"""Content-keyed probe jobs: the farm's unit of idempotent work.

A ``ProbeJob`` is a JSON document that *fully* describes one piece of
probe work -- kernel spec (by constructor reference), device oracle (by
value), data shape, seeds, budget -- plus a content key (sha256 of the
canonical payload).  Two consequences the whole farm leans on:

* **idempotence** -- executing the same job twice produces bit-identical
  results (all randomness is derived from seeds in the payload), so a
  reassigned lease or a speculative duplicate can never corrupt the
  merge: the second result is simply dropped by key;
* **dedup** -- resubmitting identical work (coordinator restart, retry)
  collapses onto the same spool entry.

Job kinds:

  ``batch``   one probe-size shard of a collect run (``collect_batch``)
  ``kernel``  a whole kernel's collect -- for strategies with cross-size
              state (successive halving survivors) that cannot shard
  ``rows``    one row-chunk of a single probe call (finest grain; noise
              comes from ``chunk_noise_seed`` so placement is invisible)
  ``retune``  a budget-capped drift reaction (search -> refit -> versioned
              cache write-through) for one ledger-fed drift key

``WallClockSim`` wraps a simulator so probe calls *take* wall-clock time
proportional to the simulated device-seconds they return: the stand-in
for real hardware where probing is expensive, and what makes fleet
speedup measurable.  Its fingerprint delegates to the inner oracle -- the
timing envelope is data-invisible, so farm-built artifacts share cache
keys with plain single-process builds.
"""

from __future__ import annotations

import hashlib
import importlib
import json
import time
from dataclasses import dataclass
from typing import Any, Callable, Mapping

import numpy as np

from repro.core.device_model import (DeviceModel, HardwareParams, RowProbe,
                                     V5E, V5P, V5eSimulator)

__all__ = [
    "JOB_KINDS", "ProbeJob", "SpecRef", "WallClockSim", "device_from_json",
    "device_to_json", "hw_by_name", "job_key", "make_job", "tier1_spec_refs",
]

JOB_KINDS = ("batch", "kernel", "rows", "retune")

_HW_BY_NAME = {V5E.name: V5E, V5P.name: V5P}


def hw_by_name(name: str) -> HardwareParams:
    try:
        return _HW_BY_NAME[name]
    except KeyError:
        raise KeyError(f"unknown hardware {name!r}; "
                       f"known: {sorted(_HW_BY_NAME)}") from None


@dataclass(frozen=True)
class SpecRef:
    """A kernel spec by constructor reference (module:function(**kwargs)).

    Jobs must be self-contained JSON, and a ``KernelSpec`` is cheap to
    rebuild from its constructor -- so jobs carry the recipe, not the
    object.  The reference is part of the job's content key.
    """

    module: str
    func: str
    kwargs: tuple = ()

    def build(self):
        fn = getattr(importlib.import_module(self.module), self.func)
        return fn(**dict(self.kwargs))

    def to_json(self) -> dict:
        return {"module": self.module, "func": self.func,
                "kwargs": [list(kv) for kv in self.kwargs]}

    @classmethod
    def from_json(cls, d: Mapping) -> "SpecRef":
        return cls(module=d["module"], func=d["func"],
                   kwargs=tuple((k, v) for k, v in d.get("kwargs", ())))


def tier1_spec_refs() -> dict[str, SpecRef]:
    """The four tier-1 kernels, keyed by their spec names."""
    refs = {}
    for func in ("matmul_spec", "flash_attention_spec", "moe_gmm_spec",
                 "ssd_scan_spec"):
        ref = SpecRef("repro.core", func)
        refs[ref.build().name] = ref
    return refs


# -- device oracles over the wire ---------------------------------------------

class WallClockSim(DeviceModel):
    """Wall-clock-faithful wrapper around a simulator oracle.

    Probe *results* delegate to the inner simulator (bit-identical data,
    same fingerprint -> same cache keys), but every ``probe_rows`` call
    sleeps ``scale`` x the simulated device-seconds it produced -- the
    farm's stand-in for a real device where probing costs real time.
    Sleeps happen in small slices with ``beat`` called between them, so a
    live worker keeps heartbeating through a long probe while a *hung*
    worker (which stops beating) is still distinguishable.
    """

    def __init__(self, inner: DeviceModel, scale: float,
                 beat: Callable[[], None] | None = None,
                 slice_s: float = 0.05):
        self.inner = inner
        self.scale = float(scale)
        self.beat = beat
        self.slice_s = float(slice_s)

    @property
    def hw(self) -> HardwareParams:  # type: ignore[override]
        return self.inner.hw

    def fingerprint(self) -> dict:
        return self.inner.fingerprint()    # timing envelope is data-invisible

    def _sleep(self, seconds: float) -> None:
        deadline = time.monotonic() + max(seconds, 0.0)
        while True:
            if self.beat is not None:
                self.beat()
            left = deadline - time.monotonic()
            if left <= 0:
                return
            time.sleep(min(left, self.slice_s))

    def probe_rows(self, table, rng, repeats=1) -> RowProbe:
        probe = self.inner.probe_rows(table, rng, repeats)
        self._sleep(float(np.sum(probe.device_seconds)) * self.scale)
        return probe

    def probe_batch(self, table, rng, repeats=1):
        batch = self.inner.probe_batch(table, rng, repeats)
        self._sleep(float(np.sum(batch.total_time_s)) * self.scale)
        return batch

    def true_time_batch(self, table) -> np.ndarray:
        return self.inner.true_time_batch(table)


def device_to_json(device: DeviceModel) -> dict:
    """Serialize a device oracle into a job payload."""
    if isinstance(device, WallClockSim):
        return {"kind": "wallclock", "scale": device.scale,
                "inner": device_to_json(device.inner)}
    if isinstance(device, V5eSimulator):
        return {"kind": "v5e_sim", "hw": device.hw.name,
                "noise": device.noise, "seed": device._seed}
    raise TypeError(
        f"cannot serialize device oracle {type(device).__name__} into a "
        f"fleet job (teach fleet.jobs.device_to_json about it)")


def device_from_json(d: Mapping,
                     beat: Callable[[], None] | None = None) -> DeviceModel:
    kind = d.get("kind")
    if kind == "wallclock":
        return WallClockSim(device_from_json(d["inner"]), d["scale"],
                            beat=beat)
    if kind == "v5e_sim":
        return V5eSimulator(hw=hw_by_name(d["hw"]), noise=d["noise"],
                            seed=d["seed"])
    raise KeyError(f"unknown device kind {kind!r}")


# -- jobs ---------------------------------------------------------------------

def _json_default(o: Any):
    if isinstance(o, np.integer):
        return int(o)
    if isinstance(o, np.floating):
        return float(o)
    if isinstance(o, np.ndarray):
        return o.tolist()
    raise TypeError(f"Object of type {type(o).__name__} "
                    f"is not JSON serializable")


def _canonical(obj: Any) -> str:
    return json.dumps(obj, sort_keys=True, separators=(",", ":"),
                      default=_json_default)


def job_key(kind: str, payload: Mapping) -> str:
    """Content address of one job: same work -> same key, always."""
    return hashlib.sha256(
        _canonical({"kind": kind, "payload": payload}).encode()).hexdigest()


@dataclass(frozen=True)
class ProbeJob:
    """One idempotent unit of farm work (see module docstring for kinds)."""

    kind: str
    payload: dict
    key: str

    def to_json(self) -> dict:
        return {"kind": self.kind, "payload": self.payload, "key": self.key}


def make_job(kind: str, payload: Mapping) -> ProbeJob:
    if kind not in JOB_KINDS:
        raise ValueError(f"unknown job kind {kind!r}; known: {JOB_KINDS}")
    payload = json.loads(_canonical(payload))   # normalize (tuples -> lists)
    return ProbeJob(kind=kind, payload=payload,
                    key=job_key(kind, payload))
