"""Fleet workers: claim -> execute -> complete over the spool board.

A worker is a pure probe executor: all run-defining state (seeds, budgets,
strategy names) arrives inside the job payload, so any worker -- or two
workers at once -- can execute any job and produce bit-identical bytes.
Heartbeats are claim-file mtimes (``JobBoard.heartbeat``), refreshed
between probe slices by the device wrapper's ``beat`` callback; the
coordinator's per-worker ``Watchdog`` watches exactly this channel.

The serve loop is wrapped in ``distributed.fault_tolerance.retry_loop``:
an unexpected crash *outside* per-job handling (per-job errors are caught
and recorded on the board) restarts the loop instead of silently losing
the worker.  ``FaultPlan`` injects the failure modes the tests and the
bench assert recovery from: a worker that dies mid-job, one that hangs
mid-job (stops heartbeating), and one that vanishes (abandons its lease
without crashing the process).
"""

from __future__ import annotations

import os
import time
from dataclasses import dataclass

import numpy as np

from repro.core.collect import ChunkedProber, collect, collect_batch
from repro.distributed.fault_tolerance import retry_loop
from repro.search import SearchBudget

from .board import JobBoard
from .jobs import SpecRef, device_from_json, hw_by_name

__all__ = ["FaultPlan", "execute_job", "run_worker"]


@dataclass
class FaultPlan:
    """Injected worker misbehavior, counted in claimed jobs (1-based).

    ``kill_at_job``    call os._exit mid-job: the process dies holding the
                       lease (process workers only)
    ``vanish_at_job``  abandon the claim and exit the loop without
                       completing (the thread-safe analogue of a kill)
    ``hang_at_job``    sleep ``hang_s`` mid-job without heartbeating, then
                       resume -- exercises lease expiry + duplicate-result
                       dropping when the sleeper eventually finishes
    """

    kill_at_job: int | None = None
    vanish_at_job: int | None = None
    hang_at_job: int | None = None
    hang_s: float = 0.0


def execute_job(job: dict, beat=None) -> dict:
    """Run one job document; returns the result payload (JSON-able).

    Deterministic by construction: every random stream is derived from
    seeds in the payload (see ``repro.core.collect``), so re-execution
    anywhere reproduces the same bytes.
    """
    kind = job["kind"]
    p = job["payload"]
    spec = SpecRef.from_json(p["spec"]).build()
    device = device_from_json(p["device"], beat=beat)
    hw = hw_by_name(p["hw"])
    budget = (SearchBudget(**p["budget"])
              if p.get("budget") is not None else None)

    if kind == "batch":
        shard = collect_batch(
            spec, device, p["D"], hw=hw, repeats=p["repeats"],
            max_configs_per_size=p["max_configs_per_size"], seed=p["seed"],
            batch_index=p["batch_index"], budget=budget,
            strategy=p.get("strategy"), max_stages=p.get("max_stages", 3),
            shard_rows=p.get("shard_rows"))
        return {"shard": shard.to_json()}

    if kind == "kernel":
        data = collect(
            spec, device, probe_data=p.get("probe_data"), hw=hw,
            repeats=p["repeats"],
            max_configs_per_size=p["max_configs_per_size"], seed=p["seed"],
            max_stages=p.get("max_stages", 3), strategy=p.get("strategy"),
            budget=budget, shard_rows=p.get("shard_rows"))
        return {"data": data.to_json()}

    if kind == "rows":
        table = spec.candidates(p["D"], hw)
        tt = spec.traffic_table(p["D"], table, hw)
        prober = ChunkedProber(device, tt, p["seed"], p["batch_index"],
                               p["shard_rows"])
        probe = prober.probe_chunk(
            np.asarray(p["indices"], dtype=np.int64),
            np.asarray(p["row_repeats"], dtype=np.int64),
            p["call_index"], p["chunk_index"])
        return {"probe": {
            "total_time_s": probe.total_time_s.tolist(),
            "mem_time_s": probe.mem_time_s.tolist(),
            "compute_time_s": probe.compute_time_s.tolist(),
            "grid_steps": probe.grid_steps.tolist(),
            "vmem_stage_bytes": probe.vmem_stage_bytes.tolist(),
            "device_seconds": probe.device_seconds.tolist(),
            "repeats": probe.repeats.tolist(),
        }}

    if kind == "retune":
        return _execute_retune(p, spec, device, hw, budget)

    raise ValueError(f"unknown job kind {kind!r}")


def _execute_retune(p: dict, spec, device, hw, budget) -> dict:
    """Run one drift reaction farm-side.

    The durable outcome is the *versioned cache write-through* (a
    corrected generation the serving fleet warm-starts/invalidates from);
    the worker-local registry hot-swap is discarded with the process.
    The serving node is never touched.
    """
    import dataclasses

    from repro.core.cache import DriverCache
    from repro.core.tuner import Klaraptor
    from repro.telemetry.config import TelemetryConfig
    from repro.telemetry.drift import DriftEvent
    from repro.telemetry.refit import RefitController

    drift = DriftEvent(
        kernel=p["drift"]["kernel"], hw_name=p["drift"]["hw"],
        bucket=tuple(), D=dict(p["drift"]["D"]),
        config=dict(p["drift"]["config"]),
        rel_error_ewma=float(p["drift"]["rel_error_ewma"]),
        n_samples=int(p["drift"].get("n_samples", 0)),
        predicted_s=float(p["drift"].get("predicted_s", 0.0)),
        observed_s=float(p["drift"].get("observed_s", 0.0)))
    cfg_kw = dict(p.get("config", {}))
    if budget is not None:
        cfg_kw["refit_budget"] = budget    # the farm's per-key budget slice
    config = TelemetryConfig(**cfg_kw)
    cache = DriverCache(p["cache_dir"])
    kl = Klaraptor(device, hw=hw, cache=cache)
    result = RefitController(kl, config, seed=p["seed"]).refit(spec, drift)
    out = dataclasses.asdict(result)
    out["budget"] = dict(result.budget)
    return {"refit": out}


def run_worker(spool, worker_id: str, poll_s: float = 0.02,
               max_jobs: int | None = None, idle_exit_s: float | None = None,
               fault: FaultPlan | None = None, max_failures: int = 3) -> int:
    """Serve jobs from the spool until stopped; returns jobs completed.

    Exits when the board's stop sentinel appears, after ``max_jobs``
    completions, or after ``idle_exit_s`` with nothing to claim.  The
    loop itself is retry-wrapped (``retry_loop``): only per-job errors
    are recorded on the board; loop-level crashes restart the loop.
    """
    board = JobBoard(spool)
    state = {"done": 0, "claimed": 0}

    def _serve(_start: int) -> None:
        idle_since = time.monotonic()
        while not board.stop_requested():
            if max_jobs is not None and state["done"] >= max_jobs:
                return
            job = board.claim(worker_id)
            if job is None:
                if idle_exit_s is not None and \
                        time.monotonic() - idle_since > idle_exit_s:
                    return
                time.sleep(poll_s)
                continue
            idle_since = time.monotonic()
            state["claimed"] += 1
            key = job["key"]
            beat = lambda: board.heartbeat(key, worker_id)  # noqa: E731
            beat()
            if fault is not None:
                if fault.kill_at_job == state["claimed"]:
                    os._exit(3)         # dies holding the lease
                if fault.vanish_at_job == state["claimed"]:
                    return              # abandons the lease, loop exits
                if fault.hang_at_job == state["claimed"]:
                    time.sleep(fault.hang_s)    # no heartbeats while asleep
            t0 = time.monotonic()
            try:
                payload = execute_job(job, beat=beat)
            except Exception as e:      # per-job failure: board bookkeeping
                board.fail(key, worker_id, repr(e))
                continue
            board.complete(key, worker_id, {
                "ok": True, "wall_seconds": time.monotonic() - t0,
                "payload": payload})
            state["done"] += 1

    retry_loop(_serve, lambda: 0, max_failures=max_failures)
    return state["done"]
