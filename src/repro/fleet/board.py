"""The spool job board: durable, lease-based work distribution on a directory.

Layout (all under one spool root, shared by coordinator and workers --
processes on one host or hosts on a shared filesystem):

    jobs/<key>.json              pending work (a ProbeJob document)
    claimed/<key>.<worker>.json  leased work; file *mtime* is the lease
                                 heartbeat (workers os.utime while alive)
    results/<key>.json           completed work (first writer wins)
    failed/<key>.json            permanently failed work (+ error history)
    stop                         sentinel: workers drain and exit

Claiming is a single atomic ``os.rename`` from jobs/ into claimed/ --
exactly one claimant can win, with no locks and no coordinator round-trip.
Every other transition is likewise one atomic rename or replace, so a
worker or coordinator killed at any instant leaves only whole files: the
board is its own crash-recovery log.  Job keys are content hashes
(``fleet.jobs.job_key``), so resubmitting identical work dedups against
every lifecycle stage, a reassigned lease re-executes to a bit-identical
result, and a duplicate result is dropped -- counted, never merged twice.
"""

from __future__ import annotations

import json
import os
import tempfile
import time

from .jobs import ProbeJob

__all__ = ["JobBoard"]

_STAGES = ("jobs", "claimed", "results", "failed")


def _write_json_atomic(path: str, doc: dict) -> None:
    d = os.path.dirname(path)
    fd, tmp = tempfile.mkstemp(prefix=".tmp.", dir=d)
    try:
        with os.fdopen(fd, "w") as f:
            json.dump(doc, f)
            f.flush()
        os.replace(tmp, path)
    except BaseException:
        try:
            os.remove(tmp)
        except OSError:
            pass
        raise


def _read_json(path: str) -> dict | None:
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, ValueError):
        return None             # vanished under us / torn: caller skips


class JobBoard:
    """One spool directory's worth of farm state (see module docstring)."""

    def __init__(self, root, max_attempts: int = 3):
        self.root = str(root)
        self.max_attempts = int(max_attempts)
        for stage in _STAGES:
            os.makedirs(os.path.join(self.root, stage), exist_ok=True)

    # -- paths ---------------------------------------------------------------
    def _dir(self, stage: str) -> str:
        return os.path.join(self.root, stage)

    def job_path(self, key: str) -> str:
        return os.path.join(self._dir("jobs"), f"{key}.json")

    def claim_path(self, key: str, worker: str) -> str:
        return os.path.join(self._dir("claimed"), f"{key}.{worker}.json")

    def result_path(self, key: str) -> str:
        return os.path.join(self._dir("results"), f"{key}.json")

    def failed_path(self, key: str) -> str:
        return os.path.join(self._dir("failed"), f"{key}.json")

    @property
    def stop_path(self) -> str:
        return os.path.join(self.root, "stop")

    # -- lifecycle -----------------------------------------------------------
    def submit(self, job: ProbeJob) -> str:
        """Enqueue a job; dedups against every stage.  Returns the stage the
        key is now in ("jobs", "claimed", "results", "failed")."""
        if os.path.exists(self.result_path(job.key)):
            return "results"
        if os.path.exists(self.failed_path(job.key)):
            return "failed"
        if self.claims_for(job.key):
            return "claimed"
        path = self.job_path(job.key)
        if not os.path.exists(path):
            _write_json_atomic(path, {**job.to_json(), "attempts": 0})
        return "jobs"

    def claim(self, worker: str) -> dict | None:
        """Atomically take one pending job; None when nothing is pending.

        Scans in sorted order so claim order is deterministic given board
        contents; the rename is the mutual exclusion -- losing a race just
        moves on to the next candidate.
        """
        jobs_dir = self._dir("jobs")
        try:
            names = sorted(os.listdir(jobs_dir))
        except OSError:
            return None
        for name in names:
            if not name.endswith(".json"):
                continue
            key = name[:-len(".json")]
            if os.path.exists(self.result_path(key)):
                # Stale duplicate (speculation that already resolved):
                # drop it rather than hand out finished work.
                try:
                    os.remove(os.path.join(jobs_dir, name))
                except OSError:
                    pass
                continue
            dst = self.claim_path(key, worker)
            try:
                os.rename(os.path.join(jobs_dir, name), dst)
            except OSError:
                continue        # lost the race; next candidate
            try:
                # rename preserved the *submit* mtime; the lease clock
                # starts now, or queued-but-unclaimed time counts against it
                os.utime(dst)
            except OSError:
                pass
            doc = _read_json(dst)
            if doc is None:
                continue
            return doc

    def heartbeat(self, key: str, worker: str) -> bool:
        """Refresh the lease mtime; False when the lease is gone (the job
        was reassigned -- the worker should abandon or finish knowing its
        result may be dropped as a duplicate)."""
        try:
            os.utime(self.claim_path(key, worker))
            return True
        except OSError:
            return False

    def complete(self, key: str, worker: str, result: dict) -> bool:
        """Record a result; first writer wins.  Returns False when a result
        for the key already existed (duplicate execution -- dropped)."""
        path = self.result_path(key)
        duplicate = os.path.exists(path)
        if not duplicate:
            _write_json_atomic(path, {"key": key, "worker": worker,
                                      **result})
        try:
            os.remove(self.claim_path(key, worker))
        except OSError:
            pass
        return not duplicate

    def fail(self, key: str, worker: str, error: str) -> str:
        """Record a job failure; requeue until ``max_attempts`` is reached,
        then park it in failed/.  Returns "jobs" or "failed"."""
        doc = _read_json(self.claim_path(key, worker))
        try:
            os.remove(self.claim_path(key, worker))
        except OSError:
            pass
        if doc is None:
            doc = _read_json(self.failed_path(key)) or {"key": key,
                                                        "attempts": 0}
        doc["attempts"] = int(doc.get("attempts", 0)) + 1
        doc.setdefault("errors", []).append({"worker": worker,
                                             "error": error})
        if doc["attempts"] >= self.max_attempts:
            _write_json_atomic(self.failed_path(key), doc)
            return "failed"
        _write_json_atomic(self.job_path(key), doc)
        return "jobs"

    # -- lease management (coordinator side) ---------------------------------
    def claims(self) -> list[tuple[str, str, float]]:
        """All live leases as (key, worker, mtime)."""
        out = []
        d = self._dir("claimed")
        for name in sorted(os.listdir(d)):
            if not name.endswith(".json"):
                continue
            stem = name[:-len(".json")]
            key, _, worker = stem.partition(".")
            try:
                mtime = os.stat(os.path.join(d, name)).st_mtime
            except OSError:
                continue        # completed under us
            out.append((key, worker, mtime))
        return out

    def claims_for(self, key: str) -> list[str]:
        return [w for k, w, _ in self.claims() if k == key]

    def _requeue(self, key: str, worker: str, reason: str) -> str | None:
        """Move one lease back to pending (or failed/ past max_attempts)."""
        src = self.claim_path(key, worker)
        doc = _read_json(src)
        if doc is None:
            return None         # completed or already requeued: nothing to do
        try:
            os.remove(src)
        except OSError:
            return None         # lost the race with complete()/fail()
        if os.path.exists(self.result_path(key)):
            return None         # finished while we were deciding
        doc["attempts"] = int(doc.get("attempts", 0)) + 1
        doc.setdefault("errors", []).append({"worker": worker,
                                             "error": reason})
        if doc["attempts"] >= self.max_attempts:
            _write_json_atomic(self.failed_path(key), doc)
            return "failed"
        _write_json_atomic(self.job_path(key), doc)
        return "jobs"

    def requeue_stale(self, lease_s: float, now: float | None = None
                      ) -> list[str]:
        """Expire leases whose heartbeat is older than ``lease_s``."""
        now = time.time() if now is None else now
        requeued = []
        for key, worker, mtime in self.claims():
            if now - mtime > lease_s:
                if self._requeue(key, worker, f"lease expired "
                                 f"({now - mtime:.2f}s > {lease_s}s)"):
                    requeued.append(key)
        return requeued

    def requeue_worker(self, worker: str, reason: str = "worker lost"
                       ) -> list[str]:
        """Reassign every lease held by one (dead/hung) worker."""
        requeued = []
        for key, w, _ in self.claims():
            if w == worker and self._requeue(key, worker, reason):
                requeued.append(key)
        return requeued

    def speculate(self, key: str) -> bool:
        """Duplicate a leased job back into jobs/ (straggler mitigation).

        The original lease keeps running; whichever execution completes
        first wins the result file and the other is dropped as a
        duplicate.  Safe because jobs are idempotent by construction.
        """
        for k, worker, _ in self.claims():
            if k != key:
                continue
            doc = _read_json(self.claim_path(key, worker))
            if doc is None or os.path.exists(self.result_path(key)) or \
                    os.path.exists(self.job_path(key)):
                return False
            _write_json_atomic(self.job_path(key), doc)
            return True
        return False

    # -- queries -------------------------------------------------------------
    def result(self, key: str) -> dict | None:
        return _read_json(self.result_path(key))

    def failure(self, key: str) -> dict | None:
        return _read_json(self.failed_path(key))

    def counts(self) -> dict:
        out = {}
        for stage in _STAGES:
            try:
                out[stage] = sum(
                    1 for n in os.listdir(self._dir(stage))
                    if n.endswith(".json"))
            except OSError:
                out[stage] = 0
        return out

    # -- worker stop sentinel ------------------------------------------------
    def request_stop(self) -> None:
        _write_json_atomic(self.stop_path, {"t": time.time()})

    def clear_stop(self) -> None:
        try:
            os.remove(self.stop_path)
        except OSError:
            pass

    def stop_requested(self) -> bool:
        return os.path.exists(self.stop_path)
