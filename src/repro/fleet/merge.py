"""The merge layer: worker results -> one canonical CollectedData.

Completion order, worker placement, retries and speculative duplicates
must all be invisible in the merged dataset.  The guarantees stack up
from below: jobs are content-keyed and idempotent (fleet.jobs), the board
keeps at most one result per key (first writer wins, duplicates dropped),
and ``merge_shards`` (core.collect) concatenates by batch index -- so the
fold here is a pure function of *which jobs ran*, which is itself fixed
by the tune request.  ``collected_equal`` is the bit-identity check the
tests and the bench gate on.
"""

from __future__ import annotations

from typing import Mapping, Sequence

import numpy as np

from repro.core.collect import BatchShard, CollectedData, merge_shards
from repro.core.kernel_spec import KernelSpec

__all__ = ["collected_equal", "merge_batch_results", "merge_kernel_result"]


def merge_batch_results(spec: KernelSpec, results: Sequence[Mapping],
                        ) -> CollectedData:
    """Fold per-batch job results (payloads with ``shard``) into one
    dataset, regardless of the order results arrived in."""
    shards = [BatchShard.from_json(r["shard"]) for r in results]
    return merge_shards(spec, shards)


def merge_kernel_result(result: Mapping) -> CollectedData:
    """Unwrap a whole-kernel job result (payload with ``data``)."""
    return CollectedData.from_json(result["data"])


def collected_equal(a: CollectedData, b: CollectedData,
                    check_stats: bool = True) -> list[str]:
    """Bit-identity comparison; returns mismatch descriptions (empty = equal).

    Wall-clock seconds are never compared (they measure the run, not the
    data); probe stats are exact -- including the float64 device-seconds
    sum, whose addition order the merge preserves.
    """
    problems = []
    if a.spec_name != b.spec_name:
        problems.append(f"spec {a.spec_name!r} != {b.spec_name!r}")
    for name, cols_a, cols_b in (("columns", a.columns, b.columns),
                                 ("metrics", a.metrics, b.metrics)):
        if sorted(cols_a) != sorted(cols_b):
            problems.append(f"{name} keys {sorted(cols_a)} != "
                            f"{sorted(cols_b)}")
            continue
        for k in cols_a:
            if not np.array_equal(cols_a[k], cols_b[k]):
                problems.append(f"{name}[{k}] differs")
    for k in ("grid_steps", "vmem_stage_bytes"):
        if not np.array_equal(getattr(a, k), getattr(b, k)):
            problems.append(f"{k} differs")
    if check_stats:
        if a.n_probe_executions != b.n_probe_executions:
            problems.append(f"n_probe_executions {a.n_probe_executions} != "
                            f"{b.n_probe_executions}")
        if a.probe_device_seconds != b.probe_device_seconds:
            problems.append(f"probe_device_seconds "
                            f"{a.probe_device_seconds!r} != "
                            f"{b.probe_device_seconds!r}")
    return problems
