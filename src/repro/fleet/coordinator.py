"""The fleet coordinator: partition, lease, supervise, merge, write through.

``FleetCoordinator.tune`` turns kernel tune requests into content-keyed
jobs on the spool board, supervises a pool of workers while they drain
it, folds the results into canonical ``CollectedData``, and runs the
fit -> codegen -> versioned ``DriverCache`` write-through -- with the
*same* cache key a single-process ``build_driver`` would compute, so a
fleet of serving nodes warm-starts from probes no single node paid for.

Partitioning modes (per kernel):

  ``batch``   one job per probe size -- the default for strategies without
              cross-size state (random, lhs); per-batch derived rngs make
              the shards bit-identical to the single-process batches
  ``kernel``  one job for the whole collect -- required when the strategy
              carries state across sizes (``Strategy.cross_size_state``)
  ``rows``    the strategy loop runs *here* and every probe call fans its
              row-chunks out as jobs (``chunk_noise_seed`` placement
              independence); finest grain, works for any strategy
  ``auto``    ``kernel`` when the strategy demands it, else ``batch``

Fault supervision wires ``distributed.fault_tolerance`` to the board's
lease mechanics: one re-armable ``Watchdog`` per worker watches the
claim-mtime heartbeat channel (fire -> leases reassigned, reset on
revival), a ``StragglerMonitor`` over per-worker job durations triggers
speculative duplicates of a slow worker's leases, and ``requeue_stale``
is the lease-expiry backstop that catches killed workers.  Everything
converges because jobs are idempotent and results first-writer-win:
reassigned, speculated and duplicate executions are dropped by key,
never double-merged.

``retune`` drains a ``RetuneQueue`` (ledger-fed drift keys) through
``retune`` jobs: search -> refit -> versioned cache write-through happens
entirely farm-side, under per-key slices of one ``SearchBudget``.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from dataclasses import dataclass, field
from typing import Mapping, Sequence

import numpy as np

from repro.core.cache import DriverCache
from repro.core.collect import (batch_budgets, concat_row_probes,
                                default_probe_data)
from repro.core.device_model import DeviceModel, HardwareParams, RowProbe, V5E
from repro.core.tuner import BuildResult, Klaraptor
from repro.distributed.fault_tolerance import StragglerMonitor, Watchdog
from repro.search import SearchBudget, resolve_strategy
from repro.trace import trace_span

from .board import JobBoard
from .jobs import (ProbeJob, SpecRef, device_to_json, make_job)
from .merge import merge_batch_results, merge_kernel_result
from .queue import RetuneQueue
from .worker import FaultPlan, run_worker

__all__ = ["FleetConfig", "FleetCoordinator", "FleetStats"]


@dataclass(frozen=True)
class FleetConfig:
    """Farm-level policy (worker pool + fault tolerance)."""

    n_workers: int = 4
    backend: str = "thread"             # "thread" | "process"
    lease_s: float = 1.5                # heartbeat timeout = lease length
    poll_s: float = 0.02
    respawn: bool = True                # replace dead workers
    max_attempts: int = 4               # per-job tries before failed/
    straggler_threshold: float = 3.0
    straggler_patience: int = 3
    job_timeout_s: float = 120.0        # _await() safety net


@dataclass
class FleetStats:
    """What supervision observed during one coordinator lifetime."""

    jobs_submitted: int = 0
    results_seen: int = 0
    requeues: int = 0
    stale_requeues: int = 0
    watchdog_fires: int = 0
    worker_deaths: int = 0
    respawns: int = 0
    speculations: int = 0
    by_kind: dict = field(default_factory=dict)


class _WorkerHandle:
    def __init__(self, wid: str, handle, watchdog: Watchdog):
        self.id = wid
        self.handle = handle
        self.watchdog = watchdog
        self.last_mtime = 0.0
        self.ewma: float | None = None
        self.lost = False

    def alive(self) -> bool:
        return self.handle.is_alive()


class FleetCoordinator:
    """Own one spool board + worker pool; see module docstring."""

    def __init__(self, spool, device: DeviceModel,
                 hw: HardwareParams = V5E,
                 cache: DriverCache | None = None,
                 config: FleetConfig | None = None,
                 worker_faults: Mapping[int, FaultPlan] | None = None):
        self.config = config or FleetConfig()
        self.board = JobBoard(spool, max_attempts=self.config.max_attempts)
        self.device = device
        self.hw = hw
        self.cache = cache if cache is not None else DriverCache()
        self.stats = FleetStats()
        self.worker_faults = dict(worker_faults or {})
        self.workers: list[_WorkerHandle] = []
        self._spawned = 0
        self._pump_stop = threading.Event()
        self._pump_thread: threading.Thread | None = None
        self._monitor: StragglerMonitor | None = None
        self._speculated: set[str] = set()
        self._seen_results: set[str] = set()
        self._lock = threading.Lock()

    # -- worker pool ---------------------------------------------------------
    def _spawn(self, fault: FaultPlan | None) -> _WorkerHandle:
        wid = f"w{self._spawned}"
        self._spawned += 1
        kwargs = dict(spool=self.board.root, worker_id=wid,
                      poll_s=self.config.poll_s, fault=fault)
        if self.config.backend == "process":
            import multiprocessing
            ctx = multiprocessing.get_context("fork")
            handle = ctx.Process(target=run_worker, kwargs=kwargs,
                                 daemon=True, name=f"fleet-{wid}")
        elif self.config.backend == "thread":
            handle = threading.Thread(target=run_worker, kwargs=kwargs,
                                      daemon=True, name=f"fleet-{wid}")
        else:
            raise ValueError(
                f"unknown backend {self.config.backend!r} "
                f"(use 'thread' or 'process')")
        wd = Watchdog(self.config.lease_s).start()
        handle.start()
        w = _WorkerHandle(wid, handle, wd)
        self.workers.append(w)
        return w

    def start(self) -> "FleetCoordinator":
        self.board.clear_stop()
        for i in range(self.config.n_workers):
            self._spawn(self.worker_faults.get(i))
        self._monitor = StragglerMonitor(
            n_hosts=len(self.workers),
            threshold=self.config.straggler_threshold,
            patience=self.config.straggler_patience)
        self._pump_stop.clear()
        self._pump_thread = threading.Thread(target=self._pump, daemon=True,
                                             name="fleet-pump")
        self._pump_thread.start()
        return self

    def stop(self) -> None:
        self.board.request_stop()
        self._pump_stop.set()
        if self._pump_thread is not None:
            self._pump_thread.join(timeout=5.0)
        for w in self.workers:
            w.watchdog.stop()
            if hasattr(w.handle, "terminate") and w.handle.is_alive():
                w.handle.join(timeout=2.0)
                if w.handle.is_alive():
                    w.handle.terminate()
            else:
                w.handle.join(timeout=2.0)

    def __enter__(self) -> "FleetCoordinator":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # -- supervision ---------------------------------------------------------
    def _pump(self) -> None:
        while not self._pump_stop.wait(self.config.poll_s):
            try:
                self._tick()
            except Exception:           # supervision must never die silently
                import logging
                logging.getLogger(__name__).exception("fleet pump tick")

    def _tick(self) -> None:
        cfg = self.config
        board = self.board
        with self._lock:
            stale = board.requeue_stale(cfg.lease_s)
            self.stats.stale_requeues += len(stale)
            self.stats.requeues += len(stale)

            held: dict[str, list[tuple[str, float]]] = {}
            for key, worker, mtime in board.claims():
                held.setdefault(worker, []).append((key, mtime))

            for w in list(self.workers):
                if w.lost:
                    continue
                if not w.alive():
                    w.lost = True
                    w.watchdog.stop()
                    requeued = board.requeue_worker(w.id, "worker died")
                    self.stats.worker_deaths += 1
                    self.stats.requeues += len(requeued)
                    if cfg.respawn and not board.stop_requested():
                        self._spawn(None)
                        self.stats.respawns += 1
                    continue
                mine = held.get(w.id, [])
                if not mine:
                    # Holding nothing: cannot be hung *on a lease*.  Keep
                    # the watchdog quiet and re-arm it if it had fired.
                    w.watchdog.beat()
                    if w.watchdog.fired:
                        w.watchdog.reset()
                    continue
                newest = max(m for _, m in mine)
                if newest > w.last_mtime:
                    w.last_mtime = newest
                    if w.watchdog.fired:
                        w.watchdog.reset()  # revived: re-arm for next time
                    else:
                        w.watchdog.beat()
                elif w.watchdog.fired:
                    # Hung: heartbeat stopped while holding leases.
                    requeued = board.requeue_worker(
                        w.id, "watchdog fired: heartbeat stopped")
                    if requeued:
                        self.stats.watchdog_fires += 1
                        self.stats.requeues += len(requeued)

            self._observe_results(held)

    def _observe_results(self, held: dict) -> None:
        """Feed new result durations to the straggler monitor; speculate
        the current leases of flagged workers."""
        import os
        rdir = os.path.join(self.board.root, "results")
        try:
            names = os.listdir(rdir)
        except OSError:
            return
        fresh = False
        for name in names:
            if not name.endswith(".json") or name in self._seen_results:
                continue
            self._seen_results.add(name)
            self.stats.results_seen += 1
            doc = self.board.result(name[:-len(".json")])
            if doc is None:
                continue
            fresh = True
            for w in self.workers:
                if w.id == doc.get("worker"):
                    dur = float(doc.get("wall_seconds", 0.0))
                    w.ewma = dur if w.ewma is None else \
                        0.5 * w.ewma + 0.5 * dur
        live = [w for w in self.workers if not w.lost]
        if not fresh or self._monitor is None or not live:
            return
        if self._monitor.n_hosts != len(live):
            self._monitor = StragglerMonitor(
                n_hosts=len(live),
                threshold=self.config.straggler_threshold,
                patience=self.config.straggler_patience)
        known = [w.ewma for w in live if w.ewma is not None]
        if not known:
            return
        default = sorted(known)[len(known) // 2]
        flagged = self._monitor.observe(
            [w.ewma if w.ewma is not None else default for w in live])
        for i in flagged:
            for key, _ in held.get(live[i].id, []):
                if key not in self._speculated and self.board.speculate(key):
                    self._speculated.add(key)
                    self.stats.speculations += 1

    # -- job submission / waiting --------------------------------------------
    def _submit(self, job: ProbeJob) -> str:
        stage = self.board.submit(job)
        self.stats.jobs_submitted += 1
        k = self.stats.by_kind
        k[job.kind] = k.get(job.kind, 0) + 1
        return stage

    def _await(self, keys: Sequence[str],
               timeout_s: float | None = None) -> dict[str, dict]:
        """Block until every key has a result; raise on failure/timeout."""
        timeout_s = timeout_s if timeout_s is not None \
            else self.config.job_timeout_s
        deadline = time.monotonic() + timeout_s
        pending = set(keys)
        out: dict[str, dict] = {}
        while pending:
            for key in sorted(pending):
                doc = self.board.result(key)
                if doc is not None:
                    out[key] = doc
                    pending.discard(key)
                    continue
                fail = self.board.failure(key)
                if fail is not None:
                    raise RuntimeError(
                        f"fleet job {key[:12]} permanently failed after "
                        f"{fail.get('attempts')} attempts: "
                        f"{fail.get('errors')}")
            if pending:
                if time.monotonic() > deadline:
                    raise TimeoutError(
                        f"fleet: {len(pending)} job(s) still unresolved "
                        f"after {timeout_s}s; board={self.board.counts()}")
                time.sleep(self.config.poll_s)
        return out

    # -- tune ----------------------------------------------------------------
    def _common_payload(self, ref: SpecRef, seed: int, repeats: int,
                        max_configs_per_size: int, strategy,
                        max_stages: int, shard_rows) -> dict:
        return {
            "spec": ref.to_json(),
            "device": device_to_json(self.device),
            "hw": self.hw.name,
            "seed": int(seed),
            "repeats": int(repeats),
            "max_configs_per_size": int(max_configs_per_size),
            "strategy": strategy,
            "max_stages": int(max_stages),
            "shard_rows": int(shard_rows) if shard_rows is not None else None,
        }

    def tune(self, spec_refs: Mapping[str, SpecRef] | Sequence[SpecRef],
             probe_data=None, repeats: int = 3,
             max_configs_per_size: int = 32, seed: int = 0,
             strategy: str | None = None, budget: SearchBudget | None = None,
             max_stages: int = 3, shard_rows: int | None = None,
             mode: str = "auto", use_cache: bool = True,
             ) -> dict[str, BuildResult]:
        """Farm one collect -> fit -> write-through per kernel.

        ``strategy`` must be a registry *name* (or None): workers
        reconstruct it from the payload.  ``probe_data`` is None, one
        shared probe list, or a per-kernel-name mapping -- exactly what
        the equivalent single-process ``build_driver`` would be given, so
        cache keys (and the collected bytes) match it.
        """
        if not isinstance(spec_refs, Mapping):
            spec_refs = {ref.build().name: ref for ref in spec_refs}
        if strategy is not None and not isinstance(strategy, str):
            raise TypeError("fleet tune takes a strategy *name*; workers "
                            "must be able to reconstruct it from JSON")
        if mode not in ("auto", "batch", "kernel", "rows"):
            raise ValueError(f"unknown mode {mode!r}")
        strat = resolve_strategy(strategy)
        kernel_mode = strat.cross_size_state
        if mode == "kernel":
            kernel_mode = True
        elif mode in ("batch", "rows"):
            if strat.cross_size_state:
                raise ValueError(
                    f"strategy {strat.name!r} carries cross-size state and "
                    f"cannot run in {mode!r} mode (use 'kernel' or 'auto')")
            kernel_mode = False
        if mode == "rows" and shard_rows is None:
            raise ValueError("mode='rows' requires shard_rows")

        def _pd_arg(name):
            if probe_data is None:
                return None
            if isinstance(probe_data, Mapping):
                return probe_data.get(name)
            return probe_data

        plans: dict[str, dict] = {}
        with trace_span("fleet.tune", kernels=sorted(spec_refs),
                        mode=mode, shard_rows=shard_rows):
            for name, ref in sorted(spec_refs.items()):
                spec = ref.build()
                pd_arg = _pd_arg(name)
                pd = list(pd_arg) if pd_arg is not None else \
                    default_probe_data(spec)
                common = self._common_payload(
                    ref, seed, repeats, max_configs_per_size, strategy,
                    max_stages, shard_rows)
                plan = {"spec": spec, "ref": ref, "pd_arg": pd_arg,
                        "pd": pd, "keys": [], "mode": None}
                if mode == "rows":
                    plan["mode"] = "rows"
                elif kernel_mode:
                    plan["mode"] = "kernel"
                    job = make_job("kernel", {
                        **common,
                        "probe_data": [{k: int(v) for k, v in d.items()}
                                       for d in pd],
                        "budget": (budget.fingerprint()
                                   if budget is not None else None)})
                    self._submit(job)
                    plan["keys"] = [job.key]
                else:
                    plan["mode"] = "batch"
                    budgets = batch_budgets(len(pd), budget,
                                            max_configs_per_size, repeats)
                    for i, (D, b) in enumerate(zip(pd, budgets)):
                        job = make_job("batch", {
                            **common,
                            "D": {k: int(v) for k, v in D.items()},
                            "batch_index": i,
                            "budget": b.fingerprint()})
                        self._submit(job)
                        plan["keys"].append(job.key)
                plans[name] = plan

            results: dict[str, BuildResult] = {}
            for name, plan in sorted(plans.items()):
                spec = plan["spec"]
                if plan["mode"] == "rows":
                    data = self._collect_rows_mode(
                        spec, plan["ref"], plan["pd"], repeats,
                        max_configs_per_size, seed, strategy, budget,
                        max_stages, shard_rows)
                else:
                    docs = self._await(plan["keys"])
                    payloads = [docs[k]["payload"] for k in plan["keys"]]
                    if plan["mode"] == "kernel":
                        data = merge_kernel_result(payloads[0])
                    else:
                        data = merge_batch_results(spec, payloads)
                kl = Klaraptor(self.device, hw=self.hw, cache=self.cache)
                results[name] = kl.build_driver(
                    spec, probe_data=plan["pd_arg"], repeats=repeats,
                    max_configs_per_size=max_configs_per_size, seed=seed,
                    strategy=strategy, budget=budget,
                    shard_rows=shard_rows, data=data, use_cache=use_cache)
        return results

    def _collect_rows_mode(self, spec, ref, pd, repeats,
                           max_configs_per_size, seed, strategy, budget,
                           max_stages, shard_rows):
        """Run the strategy loop here, farm out every probe call's chunks."""
        from repro.core.collect import collect

        coord = self

        def prober_factory(batch_index: int, D: dict, tt):
            state = {"call": 0}

            def prober(idx: np.ndarray, reps: np.ndarray) -> RowProbe:
                call = state["call"]
                state["call"] += 1
                common = coord._common_payload(
                    ref, seed, repeats, max_configs_per_size, strategy,
                    max_stages, shard_rows)
                keys = []
                for j, lo in enumerate(range(0, int(idx.size), shard_rows)):
                    sl = slice(lo, lo + shard_rows)
                    job = make_job("rows", {
                        **common,
                        "D": {k: int(v) for k, v in D.items()},
                        "batch_index": int(batch_index),
                        "call_index": int(call),
                        "chunk_index": int(j),
                        "indices": idx[sl].tolist(),
                        "row_repeats": reps[sl].tolist(),
                        "budget": None})
                    coord._submit(job)
                    keys.append(job.key)
                docs = coord._await(keys)
                parts = []
                for key in keys:
                    p = docs[key]["payload"]["probe"]
                    parts.append(RowProbe(
                        total_time_s=np.asarray(p["total_time_s"]),
                        mem_time_s=np.asarray(p["mem_time_s"]),
                        compute_time_s=np.asarray(p["compute_time_s"]),
                        grid_steps=np.asarray(p["grid_steps"],
                                              dtype=np.int64),
                        vmem_stage_bytes=np.asarray(p["vmem_stage_bytes"],
                                                    dtype=np.int64),
                        device_seconds=np.asarray(p["device_seconds"]),
                        repeats=np.asarray(p["repeats"], dtype=np.int64)))
                return concat_row_probes(parts)

            return prober

        return collect(
            spec, self.device, probe_data=pd, hw=self.hw, repeats=repeats,
            max_configs_per_size=max_configs_per_size, seed=seed,
            max_stages=max_stages, strategy=strategy, budget=budget,
            shard_rows=shard_rows, prober_factory=prober_factory)

    # -- retune --------------------------------------------------------------
    def retune(self, queue: RetuneQueue,
               spec_refs: Mapping[str, SpecRef],
               budget: SearchBudget | None = None, seed: int = 0,
               telemetry_config: dict | None = None) -> list[dict]:
        """Drain pending drift keys through farm-side retune jobs.

        One total ``budget`` is split across the pending keys (the farm
        spends a bounded amount, however long the queue).  Each completed
        job marks its key done with the refit summary; a kernel with no
        known spec ref is marked failed (nothing can rebuild it).
        """
        pend = queue.pending()
        if not pend:
            return []
        budgets = budget.split(len(pend)) if budget is not None \
            else [None] * len(pend)
        submitted: list[tuple[str, str]] = []    # (drift_key, job_key)
        with trace_span("fleet.retune", n_keys=len(pend)):
            for (dkey, event), b in zip(pend, budgets):
                ref = spec_refs.get(event.get("kernel"))
                if ref is None:
                    queue.mark_failed(
                        dkey, f"no spec ref for kernel "
                              f"{event.get('kernel')!r}")
                    continue
                job = make_job("retune", {
                    "spec": ref.to_json(),
                    "device": device_to_json(self.device),
                    "hw": self.hw.name,
                    "seed": int(seed),
                    "cache_dir": self.cache.root,
                    "config": dict(telemetry_config or {}),
                    "budget": b.fingerprint() if b is not None else None,
                    "drift": {
                        "kernel": event.get("kernel"),
                        "hw": event.get("hw"),
                        "bucket": event.get("bucket"),
                        "D": event.get("D", {}),
                        "config": event.get("config", {}),
                        "rel_error_ewma": event.get("rel_error_ewma", 0.0),
                        "n_samples": event.get("n_samples", 0),
                        "predicted_s": event.get("predicted_s", 0.0),
                        "observed_s": event.get("observed_s", 0.0),
                    }})
                self._submit(job)
                submitted.append((dkey, job.key))
            outcomes = []
            if submitted:
                docs = self._await([jk for _, jk in submitted])
                for dkey, jk in submitted:
                    summary = docs[jk]["payload"]["refit"]
                    queue.mark_done(dkey, summary)
                    outcomes.append({"key": dkey, **summary})
        return outcomes

    # -- status --------------------------------------------------------------
    def status(self) -> dict:
        return {
            "board": self.board.counts(),
            "workers": [{"id": w.id, "alive": w.alive(), "lost": w.lost,
                         "ewma_s": w.ewma,
                         "watchdog_fired": w.watchdog.fired}
                        for w in self.workers],
            "stats": dataclasses.asdict(self.stats),
        }
