"""The durable drift-retuning queue: flight ledgers in, retune jobs out.

Serving nodes persist every drift trip to their JSONL flight ledger
(``repro.trace.Ledger``; PR 7).  ``RetuneQueue`` tails those ledgers --
per-file byte offsets, advanced only past *complete* lines, survive
restarts in the queue's own state file -- deduplicates drifted
(kernel, hw, shape-bucket) keys, and hands the pending set to the fleet
coordinator, which probes and refits farm-side under one ``SearchBudget``
instead of stealing device-seconds from live serving.

The state file is one atomic JSON document: offsets, pending keys (with
the freshest drift event per key), done keys (with the refit summary),
failures, and per-key traffic tallies.  Ingest is idempotent --
re-reading a ledger only consumes bytes past the stored offset, and a
key already pending or done only bumps its counters.  Corrupt mid-file
lines are skipped and counted (the lenient ``read_ledger`` contract,
applied to tails).

Drain order is *priority*, not FIFO: the farm's device-seconds should go
where they buy the most, so ``pending()`` ranks keys by drift-EWMA
magnitude weighted by ledger traffic volume (``choice`` events tallied
per key during the same ingest pass -- a badly-drifted kernel nobody
launches ranks below a mildly-drifted hot path).  Done keys that keep
re-drifting re-enqueue themselves automatically once they trip
``requeue_after`` re-drifts (default 2): one stray drift event after a
refit stays an operator decision, a pattern of them means the refit did
not take.
"""

from __future__ import annotations

import json
import logging
import os
import tempfile

from repro.trace import LedgerTail

__all__ = ["RetuneQueue", "drift_key", "traffic_key"]

logger = logging.getLogger(__name__)


def drift_key(event: dict) -> str:
    """The dedup identity of a drifted fit: kernel x hardware x bucket."""
    return "{}|{}|{}".format(event.get("kernel", "?"), event.get("hw", "?"),
                             event.get("bucket", "?"))


def traffic_key(event: dict) -> str:
    """Traffic-tally identity of a ledger ``choice`` line.

    Choice lines carry raw ``D`` rather than a precomputed bucket label;
    bucketing it with the recorder's own log2 arithmetic makes traffic
    keys line up with the drift keys the telemetry loop writes (both go
    through ``bucket_label(shape_bucket(D))``).
    """
    bucket = event.get("bucket")
    if bucket is None and isinstance(event.get("D"), dict):
        from repro.telemetry.record import bucket_label, shape_bucket
        bucket = bucket_label(shape_bucket(event["D"]))
    return "{}|{}|{}".format(event.get("kernel", "?"), event.get("hw", "?"),
                             bucket if bucket is not None else "?")


class RetuneQueue:
    """Durable drift-key queue over one JSON state file."""

    def __init__(self, state_path, requeue_after: int = 2):
        self.state_path = str(state_path)
        self.requeue_after = max(1, int(requeue_after))
        self.state = {"offsets": {}, "pending": {}, "done": {},
                      "failed": {}, "traffic": {}, "requeued": 0,
                      "corrupt_lines": 0}
        doc = None
        try:
            with open(self.state_path) as f:
                doc = json.load(f)
        except FileNotFoundError:
            pass
        except (OSError, ValueError) as e:
            # A torn state file must not brick the farm: start fresh (the
            # worst case is re-ingesting ledgers, which dedup absorbs).
            logger.warning("retune queue state %s unreadable (%r); "
                           "starting fresh", self.state_path, e)
        if isinstance(doc, dict):
            self.state.update(doc)

    # -- persistence ---------------------------------------------------------
    def save(self) -> None:
        d = os.path.dirname(self.state_path) or "."
        fd, tmp = tempfile.mkstemp(prefix=".tmp.", dir=d)
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(self.state, f, sort_keys=True)
                f.flush()
            os.replace(tmp, self.state_path)
        except BaseException:
            try:
                os.remove(tmp)
            except OSError:
                pass
            raise

    # -- ingest --------------------------------------------------------------
    def ingest(self, ledger_path) -> int:
        """Tail one flight ledger; returns how many *new* keys were enqueued.

        Only bytes past the stored offset are read, and the offset only
        advances past complete lines -- a line the serving node is halfway
        through writing is picked up whole on the next ingest.
        """
        path = os.path.abspath(str(ledger_path))
        offset = int(self.state["offsets"].get(path, 0))
        tail = LedgerTail(path, offset=offset)
        events = tail.poll()
        if tail.offset == offset:
            return 0            # no complete new line yet
        self.state["offsets"][path] = tail.offset
        self.state["corrupt_lines"] += tail.corrupt_lines

        new_keys = 0
        for event in events:
            etype = event.get("type")
            if etype == "choice":
                # Traffic tally: how many launches each key actually
                # serves, the weight side of the drain priority.
                tk = traffic_key(event)
                self.state["traffic"][tk] = (
                    self.state["traffic"].get(tk, 0)
                    + int(event.get("n_coalesced") or 1))
                continue
            if etype != "drift":
                continue
            key = drift_key(event)
            if key in self.state["done"]:
                # Already retuned: one stray re-drift is counted but left
                # to the operator; a *pattern* of them (>= requeue_after)
                # means the refit did not take, so the key re-enqueues
                # itself.
                done = self.state["done"][key]
                done["re_drifts"] = done.get("re_drifts", 0) + 1
                if done["re_drifts"] < self.requeue_after:
                    continue
                self.state["done"].pop(key)
                self.state["requeued"] = self.state.get("requeued", 0) + 1
                self.state["pending"][key] = {"event": event, "n_seen": 1}
                new_keys += 1
                continue
            row = self.state["pending"].get(key)
            if row is None:
                self.state["pending"][key] = {"event": event, "n_seen": 1}
                new_keys += 1
            else:
                row["event"] = event        # freshest wins; key deduped
                row["n_seen"] += 1
        self.save()
        return new_keys

    # -- queue ---------------------------------------------------------------
    def enqueue(self, event: dict, boost: float = 1.0) -> bool:
        """Directly enqueue one drift-shaped event (SLO breach path).

        The observatory's SLO engine calls this when a burn-rate rule
        breaches: unlike ``ingest`` it bypasses the ledger tail (the alert
        is already in hand) and can carry a priority ``boost`` multiplier
        so acting SLO breaches outrank organically-tailed drift of the
        same magnitude.  A key already done re-enters pending -- a breach
        is stronger evidence than a single re-drift.  Returns True if the
        key is newly pending.
        """
        key = drift_key(event)
        self.state["done"].pop(key, None)
        row = self.state["pending"].get(key)
        if row is None:
            self.state["pending"][key] = {"event": event, "n_seen": 1,
                                          "boost": float(boost)}
            self.save()
            return True
        row["event"] = event
        row["n_seen"] += 1
        row["boost"] = max(float(row.get("boost", 1.0)), float(boost))
        self.save()
        return False

    def priority(self, key: str) -> float:
        """Drain priority: drift magnitude x (1 + ledger traffic weight).

        The EWMA says how wrong the fit is, the traffic tally says how
        often that wrongness is paid; a key with no recorded traffic
        still drains on magnitude alone (the +1).  SLO-breach enqueues
        multiply in their ``boost`` so acted-on alerts drain first.
        """
        row = self.state["pending"].get(key)
        if row is None:
            return 0.0
        ewma = row["event"].get("rel_error_ewma")
        mag = abs(float(ewma)) if ewma is not None else 0.0
        weight = float(self.state.get("traffic", {}).get(key, 0))
        return mag * (1.0 + weight) * float(row.get("boost", 1.0))

    def pending(self) -> list[tuple[str, dict]]:
        """Deduped pending drift keys, highest priority first (key-sorted
        within ties: deterministic job order)."""
        keys = sorted(self.state["pending"],
                      key=lambda k: (-self.priority(k), k))
        return [(k, self.state["pending"][k]["event"]) for k in keys]

    def mark_done(self, key: str, summary: dict) -> None:
        row = self.state["pending"].pop(key, None) or {}
        self.state["done"][key] = {"summary": summary,
                                   "n_seen": row.get("n_seen", 0)}
        self.save()

    def mark_failed(self, key: str, error: str) -> None:
        self.state["pending"].pop(key, None)
        self.state["failed"][key] = {"error": error}
        self.save()

    def summary(self) -> dict:
        return {
            "pending": len(self.state["pending"]),
            "done": len(self.state["done"]),
            "failed": len(self.state["failed"]),
            "ledgers": len(self.state["offsets"]),
            "corrupt_lines": self.state["corrupt_lines"],
            "re_drifts": sum(d.get("re_drifts", 0)
                             for d in self.state["done"].values()),
            "requeued": self.state.get("requeued", 0),
            "traffic_keys": len(self.state.get("traffic", {})),
        }
