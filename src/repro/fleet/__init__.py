"""``repro.fleet`` -- the fault-tolerant distributed tuning farm.

KLARAPTOR's probe phase is embarrassingly parallel but expensive: the
rational-program fits need timings at many (D, P) points, and a serving
node should neither pay for them inline nor lose them to a worker crash.
This package farms the probes out:

    ``jobs``         content-keyed idempotent job documents + device/spec
                     serialization (``SpecRef``, ``WallClockSim``)
    ``board``        the durable spool: atomic-rename claims, mtime-lease
                     heartbeats, first-writer-wins results
    ``worker``       claim -> execute -> complete loop with injectable
                     faults (``FaultPlan``) for kill/hang/vanish drills
    ``merge``        worker results -> canonical ``CollectedData``
                     (completion-order independent, bit-identical to
                     single-process ``collect``)
    ``coordinator``  partitioning, watchdog/straggler supervision, lease
                     reassignment, fit + versioned cache write-through
    ``queue``        the durable drift-retuning queue tailing PR-7 flight
                     ledgers into farm-side refits

CLI: ``python -m repro.launch.fleet {tune,retune,worker,status}``.
"""

from .board import JobBoard
from .coordinator import FleetConfig, FleetCoordinator, FleetStats
from .jobs import (ProbeJob, SpecRef, WallClockSim, device_from_json,
                   device_to_json, hw_by_name, job_key, make_job,
                   tier1_spec_refs)
from .merge import collected_equal, merge_batch_results, merge_kernel_result
from .queue import RetuneQueue, drift_key, traffic_key
from .worker import FaultPlan, execute_job, run_worker

__all__ = [
    "FaultPlan",
    "FleetConfig",
    "FleetCoordinator",
    "FleetStats",
    "JobBoard",
    "ProbeJob",
    "RetuneQueue",
    "SpecRef",
    "WallClockSim",
    "collected_equal",
    "device_from_json",
    "device_to_json",
    "drift_key",
    "traffic_key",
    "execute_job",
    "hw_by_name",
    "job_key",
    "make_job",
    "merge_batch_results",
    "merge_kernel_result",
    "run_worker",
    "tier1_spec_refs",
]
