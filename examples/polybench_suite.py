"""The paper's evaluation: KLARAPTOR over the Polybench/GPU-analogue suite.

Reproduces the Fig. 1 / Table I experiment shape: for every suite kernel,
build a driver from small-size probes, then compare its chosen launch
configuration against exhaustive search at large sizes.

    PYTHONPATH=src python examples/polybench_suite.py [--sizes 1024 2048]
"""

import argparse

import numpy as np

from benchmarks.common import build_suite_drivers
from repro.configs import polybench
from repro.core import selection_ratio


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--sizes", type=int, nargs="+", default=[1024, 2048])
    ap.add_argument("--kernels", nargs="*", default=None)
    args = ap.parse_args()

    sim, drivers = build_suite_drivers(args.kernels)
    ratios = []
    print(f"{'kernel':>16} {'N':>6} {'chosen':>14} {'best':>14} {'ratio':>6}")
    for name, (spec, build) in drivers.items():
        for D in polybench.eval_points(spec, sizes=tuple(args.sizes)):
            r = selection_ratio(spec, sim, build.driver, D)
            ratios.append(r["ratio"])
            fmt = lambda c: "x".join(str(v) for v in c.values())
            print(f"{name:>16} {list(D.values())[0]:>6} "
                  f"{fmt(r['chosen']):>14} {fmt(r['best']):>14} "
                  f"{r['ratio']:>6.3f}")
    good = sum(1 for r in ratios if r >= 0.85)
    print(f"\nmedian ratio {np.median(ratios):.3f}; "
          f"{good}/{len(ratios)} cells >= 0.85 ('good' per paper Fig. 1)")


if __name__ == "__main__":
    main()
