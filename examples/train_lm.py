"""End-to-end training driver: data pipeline -> sharded step -> checkpoints.

Defaults to a ~100M-parameter llama-family model on synthetic data.  On this
CPU container a full few-hundred-step run at 100M is hours; pass --tiny for
the fast demonstration config (~10M params, minutes) -- the loop, the
checkpointing, and the loss trend are identical machinery.

    PYTHONPATH=src python examples/train_lm.py --tiny --steps 120
"""

import argparse

from repro.configs import get_config
from repro.configs.base import ShapePreset
from repro.launch.train import TrainLoop


def model_100m():
    return get_config("llama3.2-1b").replace(
        name="llama-100m", n_layers=8, d_model=768, n_heads=12,
        n_kv_heads=4, head_dim=64, d_ff=2048, vocab_size=32000,
        remat="none", logits_chunk=256)


def model_tiny():
    return get_config("llama3.2-1b", smoke=True).replace(
        name="llama-tiny", n_layers=4, d_model=256, n_heads=4, n_kv_heads=2,
        head_dim=64, d_ff=512, vocab_size=2048, logits_chunk=128)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    cfg = model_tiny() if args.tiny else model_100m()
    from repro.models import Model
    print(f"arch={cfg.name} params={Model(cfg).param_count() / 1e6:.1f}M "
          f"batch={args.batch} seq={args.seq}")

    preset = ShapePreset("train", "train", args.seq, args.batch)
    loop = TrainLoop(cfg, preset, mesh=None, ckpt_dir=args.ckpt_dir,
                     ckpt_every=100)
    loop.restore_or_init()
    hist = loop.run(args.steps, log_every=10)
    for m in hist:
        print(f"step {m['step']:5d}  loss {m['loss']:.4f}  "
              f"lr {m['lr']:.2e}  {m['step_time_s'] * 1e3:.0f} ms/step")
    first, last = hist[0]["loss"], hist[-1]["loss"]
    print(f"\nloss {first:.3f} -> {last:.3f} "
          f"({'DECREASED' if last < first else 'NOT DECREASED'})")


if __name__ == "__main__":
    main()
