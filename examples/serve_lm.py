"""End-to-end serving driver: batched requests through the continuous-
batching engine (more requests than decode slots -> slots are recycled).

    PYTHONPATH=src python examples/serve_lm.py --requests 10 --batch 4

``--async`` routes the same workload through the engine's async
front-end: a scheduler thread owns the device loop, ``submit`` is
thread-safe (requests here are submitted *while the engine is already
running*), and prefill advances in chunked jitted scans -- the prompt is
split into descending power-of-two chunks -- instead of one Python
round-trip per prompt token.  Greedy outputs are identical to the
synchronous path; the printout adds the compile counts, which stay at
one decode-step trace and at most log2(prefill_chunk)+1 prefill-chunk
traces regardless of how many distinct prompt lengths arrive.

``--trace out.json`` records the whole run (engine bring-up, prefill,
decode steps, kernel dispatch) as a nested span tree and writes a Chrome
trace-event file to load in ui.perfetto.dev.
"""

import argparse
import time

from repro.configs import get_config
from repro.launch.serve import build_engine
from repro.serving import Request


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=96)
    ap.add_argument("--requests", type=int, default=10)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--async", dest="run_async", action="store_true",
                    help="serve through the async front-end (scheduler "
                         "thread + chunked prefill); requests are "
                         "submitted while the engine is running")
    ap.add_argument("--trace", metavar="PATH", default=None,
                    help="write a Chrome trace-event JSON of the run")
    args = ap.parse_args()

    tracer = None
    if args.trace:
        from repro.trace import Tracer
        tracer = Tracer()
    cfg = get_config(args.arch, smoke=True)
    engine = build_engine(cfg, args.batch, args.max_seq, trace=tracer)
    t0 = time.perf_counter()
    if args.run_async:
        # Submit-while-running: the scheduler thread picks requests up as
        # they arrive, which is the whole point of the async front-end.
        engine.start()
    for i in range(args.requests):
        prompt = [2 + (13 * i + j) % (cfg.vocab_size - 4)
                  for j in range(3 + i % 5)]
        engine.submit(Request(rid=i, prompt=prompt,
                              max_new_tokens=args.max_new,
                              temperature=0.0 if i % 2 == 0 else 0.8))
    if args.run_async:
        finished = engine.drain()
        engine.stop()
    else:
        finished = engine.run()
    dt = time.perf_counter() - t0
    toks = sum(len(r.output) for r in finished)
    for r in sorted(finished, key=lambda r: r.rid):
        print(f"req {r.rid:2d} ({'greedy' if r.temperature == 0 else 'T=.8'})"
              f": {r.prompt} -> {r.output}")
    print(f"\n{len(finished)} requests, {toks} tokens in {dt:.1f}s through "
          f"{args.batch} continuous-batching slots "
          f"({toks / dt:.1f} tok/s on CPU)")
    if args.run_async:
        cc = engine.compile_counts
        print(f"async front-end: {cc['decode_step']} decode-step "
              f"compile(s), {cc['prefill_chunk']} prefill-chunk compile(s) "
              f"across {args.requests} mixed-length prompts "
              f"(chunk={engine.prefill_chunk})")
    if engine._step_plan is not None:
        sp = engine._step_plan.describe()
        print(f"step plan: {sp['entries']} kernel configs frozen at "
              f"registry generation {sp['generation']} "
              f"(sources: {sp['sources']}) -- traced decode steps dispatch "
              f"from the frozen table, zero registry round-trips")
    from repro.core.driver import registry
    print(f"decision-memo hits this run: {registry.memo_hits()}")
    if tracer is not None:
        n = tracer.write_chrome_trace(args.trace)
        tracer.uninstall()
        print(f"trace: {n} spans -> {args.trace} (open in ui.perfetto.dev)")


if __name__ == "__main__":
    main()
