"""End-to-end serving driver: batched requests through the continuous-
batching engine (more requests than decode slots -> slots are recycled).

    PYTHONPATH=src python examples/serve_lm.py --requests 10 --batch 4

``--trace out.json`` records the whole run (engine bring-up, prefill,
decode steps, kernel dispatch) as a nested span tree and writes a Chrome
trace-event file to load in ui.perfetto.dev.
"""

import argparse
import time

from repro.configs import get_config
from repro.launch.serve import build_engine
from repro.serving import Request


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=96)
    ap.add_argument("--requests", type=int, default=10)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--trace", metavar="PATH", default=None,
                    help="write a Chrome trace-event JSON of the run")
    args = ap.parse_args()

    tracer = None
    if args.trace:
        from repro.trace import Tracer
        tracer = Tracer()
    cfg = get_config(args.arch, smoke=True)
    engine = build_engine(cfg, args.batch, args.max_seq, trace=tracer)
    t0 = time.perf_counter()
    for i in range(args.requests):
        prompt = [2 + (13 * i + j) % (cfg.vocab_size - 4)
                  for j in range(3 + i % 5)]
        engine.submit(Request(rid=i, prompt=prompt,
                              max_new_tokens=args.max_new,
                              temperature=0.0 if i % 2 == 0 else 0.8))
    finished = engine.run()
    dt = time.perf_counter() - t0
    toks = sum(len(r.output) for r in finished)
    for r in sorted(finished, key=lambda r: r.rid):
        print(f"req {r.rid:2d} ({'greedy' if r.temperature == 0 else 'T=.8'})"
              f": {r.prompt} -> {r.output}")
    print(f"\n{len(finished)} requests, {toks} tokens in {dt:.1f}s through "
          f"{args.batch} continuous-batching slots "
          f"({toks / dt:.1f} tok/s on CPU)")
    if engine._step_plan is not None:
        sp = engine._step_plan.describe()
        print(f"step plan: {sp['entries']} kernel configs frozen at "
              f"registry generation {sp['generation']} "
              f"(sources: {sp['sources']}) -- traced decode steps dispatch "
              f"from the frozen table, zero registry round-trips")
    from repro.core.driver import registry
    print(f"decision-memo hits this run: {registry.memo_hits()}")
    if tracer is not None:
        n = tracer.write_chrome_trace(args.trace)
        tracer.uninstall()
        print(f"trace: {n} spans -> {args.trace} (open in ui.perfetto.dev)")


if __name__ == "__main__":
    main()
