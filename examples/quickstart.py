"""Quickstart: KLARAPTOR end to end on one kernel.

Builds a driver program for the tiled-matmul Pallas kernel against the
simulated TPU v5e (compile-time phase: probe small sizes -> SVD-fit rational
functions -> generate driver code), then uses it at "runtime" to pick launch
parameters for data sizes it never saw, comparing against exhaustive search.

    PYTHONPATH=src python examples/quickstart.py
"""

from repro.core import (Klaraptor, V5eSimulator, exhaustive_search,
                        matmul_spec, selection_ratio)


def main() -> None:
    sim = V5eSimulator(noise=0.04, seed=42)
    spec = matmul_spec()

    print("== compile-time: probe + fit + codegen ==")
    build = Klaraptor(sim).build_driver(spec, repeats=2,
                                        max_configs_per_size=24)
    print(build.fit_report())

    print("\n== generated driver program (excerpt) ==")
    src = build.driver.source.splitlines()
    head = [ln for ln in src if ln.startswith("def ")][:6]
    print("\n".join(head))

    print("\n== runtime: choose launch parameters per data size ==")
    print(f"{'N':>6} {'chosen':>18} {'t_chosen':>10} {'best':>18} "
          f"{'t_best':>10} {'ratio':>6}")
    for n in (1024, 2048, 4096, 8192, 16384):
        D = {"m": n, "n": n, "k": n}
        r = selection_ratio(spec, sim, build.driver, D)
        fmt = lambda c: "x".join(str(v) for v in c.values())
        print(f"{n:>6} {fmt(r['chosen']):>18} "
              f"{r['chosen_time_s'] * 1e3:>8.3f}ms {fmt(r['best']):>18} "
              f"{r['best_time_s'] * 1e3:>8.3f}ms {r['ratio']:>6.3f}")

    print("\nratios >= 0.85 are 'good' per the paper (Fig. 1); the driver "
          "probed only N <= 1024.")


if __name__ == "__main__":
    main()
