"""repro.search tests: determinism, hard budgets, strategy quality, and the
strategy-aware cache keys / escalation paths of the consumers."""

import logging

import numpy as np
import pytest

from repro.core import (Klaraptor, V5eSimulator, exhaustive_search,
                        matmul_spec, moe_gmm_spec, search_best,
                        ssd_scan_spec)
from repro.core.collect import default_probe_data
from repro.core.driver import choose_or_default, registry
from repro.search import (STRATEGIES, SearchBudget, make_strategy,
                          run_search)

D_MM = {"m": 4096, "n": 4096, "k": 4096}


@pytest.fixture(scope="module")
def sim():
    return V5eSimulator(noise=0.04, seed=11)


@pytest.fixture(scope="module")
def exhaustive_mm(sim):
    return exhaustive_search(matmul_spec(), sim, D_MM)


class TestBudget:
    def test_split_conserves_totals(self):
        b = SearchBudget(max_executions=10, max_device_seconds=1.0)
        parts = b.split(3)
        assert sum(p.max_executions for p in parts) == 10
        assert sum(p.max_device_seconds for p in parts) == pytest.approx(1.0)

    def test_unbounded_axes_stay_unbounded(self):
        parts = SearchBudget().split(4)
        assert all(p.max_executions is None for p in parts)
        assert all(p.max_device_seconds is None for p in parts)

    @pytest.mark.parametrize("name", sorted(STRATEGIES))
    def test_execution_budget_never_exceeded(self, sim, name):
        budget = SearchBudget(max_executions=17)
        r = run_search(matmul_spec(), sim, D_MM, strategy=name,
                       budget=budget, seed=3)
        assert 0 < r.n_probe_executions <= 17
        assert r.best_config is not None

    @pytest.mark.parametrize("name", sorted(STRATEGIES))
    def test_device_seconds_budget_never_exceeded(self, sim, name,
                                                  exhaustive_mm):
        _, _, _, exhaustive_s = exhaustive_mm
        cap = 0.1 * exhaustive_s
        r = run_search(matmul_spec(), sim, D_MM, strategy=name,
                       budget=SearchBudget(max_device_seconds=cap), seed=3)
        assert 0.0 < r.probe_device_seconds <= cap
        assert r.best_config is not None

    def test_both_axes_enforced_together(self, sim, exhaustive_mm):
        _, _, _, exhaustive_s = exhaustive_mm
        budget = SearchBudget(max_executions=40,
                              max_device_seconds=0.05 * exhaustive_s)
        for name in sorted(STRATEGIES):
            r = run_search(matmul_spec(), sim, D_MM, strategy=name,
                           budget=budget, seed=9)
            assert r.n_probe_executions <= 40, name
            assert r.probe_device_seconds <= 0.05 * exhaustive_s, name


class TestDeterminism:
    @pytest.mark.parametrize("name", sorted(STRATEGIES))
    def test_fixed_seed_reproduces_run(self, sim, name):
        budget = SearchBudget(max_executions=48)
        a = run_search(matmul_spec(), sim, D_MM, strategy=name,
                       budget=budget, seed=17)
        b = run_search(matmul_spec(), sim, D_MM, strategy=name,
                       budget=budget, seed=17)
        assert a.best_config == b.best_config
        assert a.n_probe_executions == b.n_probe_executions
        assert a.probe_device_seconds == pytest.approx(
            b.probe_device_seconds)

    def test_different_seed_may_differ_but_stays_valid(self, sim):
        budget = SearchBudget(max_executions=24)
        r = run_search(matmul_spec(), sim, D_MM, strategy="random",
                       budget=budget, seed=101)
        assert set(r.best_config) == {"bm", "bn", "bk"}


class TestStrategyQuality:
    def test_halving_beats_random_at_equal_budget(self, sim, exhaustive_mm):
        """Successive halving's noise-aware refinement must reach at least
        random's selection ratio for the same device-second budget."""
        best_P, best_t, _, exhaustive_s = exhaustive_mm
        budget = SearchBudget(max_device_seconds=0.25 * exhaustive_s)
        spec = matmul_spec()

        def ratio(name):
            r = run_search(spec, sim, D_MM, strategy=name, budget=budget,
                           seed=29)
            times = sim.true_time_batch(spec.traffic_table(
                D_MM, spec.candidates(D_MM).select(
                    np.array([r.best_index]))))
            return best_t / float(times[0])

        assert ratio("successive_halving") >= ratio("random")

    def test_some_strategy_is_good_within_quarter_budget(self, sim,
                                                         exhaustive_mm):
        """The acceptance bar: ratio >= 0.85 at <= 25% of exhaustive probe
        device-seconds on matmul."""
        best_P, best_t, _, exhaustive_s = exhaustive_mm
        budget = SearchBudget(max_device_seconds=0.25 * exhaustive_s)
        spec = matmul_spec()
        ratios = {}
        for name in sorted(STRATEGIES):
            r = run_search(spec, sim, D_MM, strategy=name, budget=budget,
                           seed=29)
            t = float(sim.true_time_batch(spec.traffic_table(
                D_MM, spec.candidates(D_MM).select(
                    np.array([r.best_index]))))[0])
            ratios[name] = best_t / t
        assert max(ratios.values()) >= 0.85, ratios

    def test_search_best_facade(self, sim):
        r = search_best(matmul_spec(), sim, D_MM, strategy="surrogate",
                        budget=SearchBudget(max_executions=64), seed=5)
        assert r.kernel == "matmul_b16"
        assert r.strategy["name"] == "surrogate"
        assert set(r.best_config) == {"bm", "bn", "bk"}
        assert r.n_probe_executions <= 64


class TestCollectIntegration:
    def test_cache_key_separates_strategies(self, sim, tmp_path,
                                            monkeypatch):
        """Same spec, same hyperparams, different strategy -> different
        cache artifact (a rebuild, not a hit)."""
        monkeypatch.setenv("KLARAPTOR_CACHE_DIR", str(tmp_path / "c"))
        kl = Klaraptor(V5eSimulator(noise=0.03, seed=5))
        first = kl.build_driver(matmul_spec(), repeats=2,
                                max_configs_per_size=16, register=False,
                                strategy="random")
        assert not first.from_cache
        second = kl.build_driver(matmul_spec(), repeats=2,
                                 max_configs_per_size=16, register=False,
                                 strategy="lhs")
        assert not second.from_cache
        again = kl.build_driver(matmul_spec(), repeats=2,
                                max_configs_per_size=16, register=False,
                                strategy="random")
        assert again.from_cache

    def test_collect_respects_total_budget(self, sim):
        from repro.core.collect import collect
        budget = SearchBudget(max_executions=60)
        data = collect(matmul_spec(), sim, repeats=2, budget=budget)
        assert 0 < data.n_probe_executions <= 60

    def test_halving_carries_survivors_across_sizes(self, sim):
        """With a multi-size collect, successive halving probes fewer rows
        at the later sizes (only survivors), not the whole table."""
        from repro.core.collect import collect
        strat = make_strategy("successive_halving")
        data = collect(matmul_spec(), sim,
                       probe_data=[{"m": 256, "n": 256, "k": 256},
                                   {"m": 1024, "n": 1024, "k": 1024}],
                       repeats=2, strategy=strat)
        cols = data.columns
        small = cols["m"] == 256
        large = cols["m"] == 1024
        # distinct configs probed at the large size <= survivors of small
        small_cfgs = {tuple(r) for r in np.stack(
            [cols[p][small] for p in ("bm", "bn", "bk")], axis=1)}
        large_cfgs = {tuple(r) for r in np.stack(
            [cols[p][large] for p in ("bm", "bn", "bk")], axis=1)}
        assert 0 < len(large_cfgs) < len(small_cfgs)

    def test_probe_hints_override_default_sweep(self):
        spec = moe_gmm_spec()
        assert spec.probe_hints["e"] == (2, 4)
        pts = default_probe_data(spec)
        assert {p["e"] for p in pts} == {2, 4}
        custom = ssd_scan_spec()
        custom.probe_hints = {"bh": (3,), "chunkflops": (1,)}
        pts = default_probe_data(custom, sizes=(128,))
        assert pts == [{"bh": 3, "s": 128, "chunkflops": 1}]


class TestEscalation:
    def test_choose_or_default_escalates_to_search(self, sim, tmp_path,
                                                   monkeypatch):
        monkeypatch.setenv("KLARAPTOR_CACHE_DIR", str(tmp_path / "empty"))
        registry.clear()
        default = {"bm": -1, "bn": -1, "bk": -1}
        # without spec/device: static default (the old behavior)
        assert choose_or_default("matmul_b16", D_MM, default) == default
        # opt-in: spec+device escalate to a budgeted online search
        cfg = choose_or_default(
            "matmul_b16", D_MM, default, spec=matmul_spec(), device=sim,
            budget=SearchBudget(max_executions=32))
        assert cfg != default and set(cfg) == {"bm", "bn", "bk"}
        # memoized: the second call must not search again (same object back)
        again = choose_or_default(
            "matmul_b16", D_MM, default, spec=matmul_spec(), device=sim)
        assert again == cfg
        registry.clear()

    def test_escalates_past_mismatched_driver(self, sim, tmp_path,
                                              monkeypatch):
        """A registered driver that raises on these data params must not
        short-circuit the opt-in search escalation."""
        from repro.core import flash_attention_spec
        from repro.core.driver import DriverProgram, register_driver
        monkeypatch.setenv("KLARAPTOR_CACHE_DIR", str(tmp_path / "empty"))
        registry.clear()
        kl = Klaraptor(V5eSimulator(noise=0.03, seed=5), cache=False)
        build = kl.build_driver(matmul_spec(), repeats=2,
                                max_configs_per_size=16, register=False)
        spec = flash_attention_spec()
        register_driver(DriverProgram(
            kernel=spec.name, source=build.driver.source,
            namespace=build.driver.namespace))
        D = {"bh": 8, "sq": 2048, "skv": 2048}
        default = {"bq": -1, "bkv": -1}
        assert choose_or_default(spec.name, D, default) == default
        cfg = choose_or_default(spec.name, D, default, spec=spec,
                                device=sim,
                                budget=SearchBudget(max_executions=16))
        assert cfg != default and set(cfg) == {"bq", "bkv"}
        registry.clear()

    def test_unknown_strategy_name_raises(self, sim, tmp_path, monkeypatch):
        """A typo'd strategy name is a configuration error, not a silent
        fallback to the static default."""
        monkeypatch.setenv("KLARAPTOR_CACHE_DIR", str(tmp_path / "empty"))
        registry.clear()
        with pytest.raises(ValueError, match="unknown search strategy"):
            choose_or_default("matmul_b16", D_MM,
                              {"bm": -1, "bn": -1, "bk": -1},
                              spec=matmul_spec(), device=sim,
                              strategy="surogate")
        registry.clear()

    def test_tune_for_shape_survives_mismatched_driver(self, sim, tmp_path,
                                                       monkeypatch):
        """A warm-started driver built for other data params must not crash
        the serving path: tune_for_shape falls back to the online search."""
        from repro.core import flash_attention_spec
        from repro.core.driver import DriverProgram, register_driver
        from repro.serving.engine import ServingEngine
        monkeypatch.setenv("KLARAPTOR_CACHE_DIR", str(tmp_path / "c"))
        registry.clear()
        kl = Klaraptor(V5eSimulator(noise=0.03, seed=5))
        build = kl.build_driver(matmul_spec(), repeats=2,
                                max_configs_per_size=16, register=False)
        spec = flash_attention_spec()
        # a matmul driver registered under the flash kernel's name: its
        # choose() raises on the flash data params
        register_driver(DriverProgram(
            kernel=spec.name, source=build.driver.source,
            namespace=build.driver.namespace))
        engine = ServingEngine.__new__(ServingEngine)
        D = {"bh": 8, "sq": 2048, "skv": 2048}
        cfg = engine.tune_for_shape(spec, D, sim,
                                    budget=SearchBudget(max_executions=16))
        assert set(cfg) == {"bq", "bkv"}
        registry.clear()

    def test_cache_write_failure_warns_once(self, tmp_path, monkeypatch,
                                            caplog):
        """Read-only cache dir: the build succeeds and logs one warning
        naming the cache path (satellite: diagnosable serving nodes)."""
        import repro.core.tuner as tuner_mod
        # A cache root nested under a regular *file* makes every write fail
        # with NotADirectoryError (an OSError) -- works even when the test
        # runs as root, where chmod-based read-only dirs are ignored.
        blocker = tmp_path / "blocker"
        blocker.write_text("")
        ro = blocker / "cache"
        monkeypatch.setenv("KLARAPTOR_CACHE_DIR", str(ro))
        monkeypatch.setattr(tuner_mod.Klaraptor, "_cache_write_warned",
                            False)
        kl = Klaraptor(V5eSimulator(noise=0.03, seed=5))
        with caplog.at_level(logging.WARNING, logger="repro.core.tuner"):
            kl.build_driver(matmul_spec(), repeats=1,
                            max_configs_per_size=8, register=False)
            kl.build_driver(matmul_spec(), repeats=1,
                            max_configs_per_size=9, register=False)
        warnings = [r for r in caplog.records
                    if "cache write failed" in r.message]
        assert len(warnings) == 1
        assert str(ro) in warnings[0].message
