"""Substrate tests: optimizer, data pipeline, checkpointing, compression."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:            # hypothesis is a dev-only extra; only the
    HAVE_HYPOTHESIS = False    # property test skips without it

    def settings(**kw):
        return lambda fn: fn

    def given(*a, **kw):
        def deco(fn):
            def test_skipped(self):
                pytest.skip("hypothesis not installed "
                            "(pip install -r requirements-dev.txt)")
            test_skipped.__name__ = fn.__name__
            return test_skipped
        return deco

    class st:  # noqa: N801 - stand-in for hypothesis.strategies
        @staticmethod
        def integers(*a, **kw):
            return None

from repro.checkpoint import CheckpointManager
from repro.data import Prefetcher, SyntheticConfig, SyntheticStream
from repro.optim import (AdamWConfig, adamw_init, adamw_update,
                         clip_by_global_norm, compress, decompress,
                         ef_compress_tree, ef_update_tree,
                         init_error_feedback, warmup_cosine)


class TestAdamW:
    def test_quadratic_convergence(self):
        cfg = AdamWConfig(lr=0.1, weight_decay=0.0, clip_norm=None)
        params = {"w": jnp.array([5.0, -3.0])}
        state = adamw_init(cfg, params)
        target = jnp.array([1.0, 2.0])
        for _ in range(300):
            grads = {"w": params["w"] - target}
            params, state, _ = adamw_update(cfg, grads, state, params)
        np.testing.assert_allclose(params["w"], target, atol=1e-2)

    def test_weight_decay_shrinks(self):
        cfg = AdamWConfig(lr=0.1, weight_decay=0.5, clip_norm=None)
        params = {"w": jnp.array([4.0])}
        state = adamw_init(cfg, params)
        params2, _, _ = adamw_update(cfg, {"w": jnp.array([0.0])}, state,
                                     params)
        assert float(params2["w"][0]) < 4.0

    def test_clip(self):
        tree = {"a": jnp.array([3.0, 4.0])}       # norm 5
        clipped, norm = clip_by_global_norm(tree, 1.0)
        assert float(norm) == pytest.approx(5.0)
        assert float(jnp.linalg.norm(clipped["a"])) == pytest.approx(1.0)

    def test_schedule(self):
        fn = warmup_cosine(1.0, 10, 100)
        assert float(fn(jnp.asarray(5))) == pytest.approx(0.5)
        assert float(fn(jnp.asarray(10))) == pytest.approx(1.0)
        assert float(fn(jnp.asarray(100))) == pytest.approx(0.0, abs=1e-6)


class TestCompression:
    @settings(max_examples=20, deadline=None)
    @given(st.integers(0, 10_000))
    def test_roundtrip_bounded_error(self, seed):
        rng = np.random.RandomState(seed)
        g = jnp.asarray(rng.randn(64) * rng.uniform(0.1, 10))
        q, s = compress(g)
        back = decompress(q, s)
        assert float(jnp.max(jnp.abs(back - g))) <= float(s) * 0.5 + 1e-9

    def test_error_feedback_accumulates_residual(self):
        rng = np.random.RandomState(0)
        grads = {"w": jnp.asarray(rng.randn(128) * 0.01)}
        err = init_error_feedback(grads)
        # with EF, the *cumulative* applied update approaches the cumulative
        # true gradient (residual is bounded, not growing)
        applied = jnp.zeros(128)
        true = jnp.zeros(128)
        for step in range(30):
            g = {"w": jnp.asarray(rng.randn(128) * 0.01)}
            qs, ss, err = ef_compress_tree(g, err)
            deq = ef_update_tree(qs, ss)
            applied = applied + deq["w"]
            true = true + g["w"].astype(jnp.float32)
        resid = float(jnp.max(jnp.abs(applied + err["w"] - true)))
        assert resid < 1e-4   # applied + pending residual == truth


class TestSyntheticData:
    def test_deterministic_and_resumable(self):
        cfg = SyntheticConfig(vocab_size=1000, seq_len=32, global_batch=4)
        s1 = SyntheticStream(cfg)
        batches = [s1.next_batch() for _ in range(5)]
        s2 = SyntheticStream(cfg)
        s2.load_state_dict({"step": 3})
        np.testing.assert_array_equal(s2.next_batch()["tokens"],
                                      batches[3]["tokens"])

    def test_shards_are_disjoint_slices(self):
        cfg = SyntheticConfig(vocab_size=1000, seq_len=16, global_batch=8)
        full = SyntheticStream(cfg, shard_index=0, shard_count=1).next_batch()
        a = SyntheticStream(cfg, shard_index=0, shard_count=2).next_batch()
        b = SyntheticStream(cfg, shard_index=1, shard_count=2).next_batch()
        np.testing.assert_array_equal(
            np.concatenate([a["tokens"], b["tokens"]]), full["tokens"])

    def test_prefetcher_resume(self):
        cfg = SyntheticConfig(vocab_size=100, seq_len=8, global_batch=2)
        p = Prefetcher(SyntheticStream(cfg), depth=2).start()
        got = [p.next_batch() for _ in range(4)]
        state = p.state_dict()
        p.stop()
        p2 = Prefetcher(SyntheticStream(cfg), depth=2)
        p2.load_state_dict(state)
        p2.start()
        nxt = p2.next_batch()
        p2.stop()
        ref = SyntheticStream(cfg)
        ref.load_state_dict({"step": 4})
        np.testing.assert_array_equal(nxt["tokens"],
                                      ref.next_batch()["tokens"])


class TestCheckpoint:
    def _tree(self):
        return {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
                "b": {"c": jnp.ones((4,), jnp.bfloat16)}}

    def test_roundtrip(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), async_save=False)
        tree = self._tree()
        mgr.save(7, tree, aux={"stream": {"step": 7}})
        restored, aux, step = mgr.restore(tree)
        assert step == 7 and aux["stream"]["step"] == 7
        for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(x, np.float32),
                                          np.asarray(y, np.float32))
            assert x.dtype == y.dtype

    def test_async_and_keep_k(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=2, async_save=True)
        tree = self._tree()
        for s in (1, 2, 3, 4):
            mgr.save(s, tree)
        mgr.wait()
        assert mgr.all_steps() == [3, 4]

    def test_no_partial_checkpoints(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), async_save=False)
        mgr.save(1, self._tree())
        for name in os.listdir(tmp_path):
            assert not name.endswith(".tmp")

    def test_shape_mismatch_raises(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), async_save=False)
        mgr.save(1, self._tree())
        bad = {"a": jnp.zeros((3, 3)), "b": {"c": jnp.ones((4,),
                                                           jnp.bfloat16)}}
        with pytest.raises(ValueError):
            mgr.restore(bad)
