"""Compiled launch plans: batched choose_many, the LaunchPlanTable hot
path, plan artifacts in the cache, and invalidation on refit/hot-swap.

The load-bearing property is exact agreement: ``choose_many`` must pick the
bit-identical config that per-shape ``choose`` picks (same occupancy-margin
tie-break) on every tier-1 kernel, and a plan entry must never outlive the
driver generation it was compiled from.
"""

import json

import numpy as np
import pytest

from repro.core import (DriverCache, DriverProgram, Klaraptor, PlanEntry,
                        V5E, V5eSimulator, choose_or_default, compile_plan,
                        flash_attention_spec, lattice, matmul_spec,
                        moe_gmm_spec, precompile_plans, registry,
                        set_choice_listener, ssd_scan_spec,
                        warm_start_from_cache)
from repro.core.plan import LaunchPlanTable, pack_shape, plan_key

SPECS = {
    "matmul": matmul_spec,
    "flash": flash_attention_spec,
    "moe": moe_gmm_spec,
    "ssd": ssd_scan_spec,
}

ENVELOPES = {
    "matmul": {"m": [512, 1024, 2048, 4096], "n": [512, 1024, 2048, 4096],
               "k": [512, 1024]},
    "flash": {"bh": [2, 8], "sq": [512, 1024, 2048, 4096],
              "skv": [1024, 2048]},
    "moe": {"e": [2, 8], "g": [256, 1024], "k": [512, 1024],
            "n": [512, 1024]},
    "ssd": {"bh": [2, 8], "s": [1024, 2048, 4096], "chunkflops": [1]},
}


@pytest.fixture(scope="module")
def builds():
    """One driver per tier-1 spec, built once (registry untouched)."""
    sim = V5eSimulator(noise=0.03, seed=7)
    kl = Klaraptor(sim, cache=False)
    return {name: kl.build_driver(fn(), repeats=2, max_configs_per_size=16,
                                  register=False)
            for name, fn in SPECS.items()}


@pytest.fixture()
def clean(tmp_path, monkeypatch):
    monkeypatch.setenv("KLARAPTOR_CACHE_DIR", str(tmp_path / "cache"))
    registry.clear()
    set_choice_listener(None)
    yield
    registry.clear()
    set_choice_listener(None)


def _rows(driver, cols):
    n = next(iter(cols.values())).shape[0]
    return [{d: int(cols[d][i]) for d in driver.data_params}
            for i in range(n)]


# ---------------------------------------------------------------------------
# choose_many: batched selection must agree exactly with choose()
# ---------------------------------------------------------------------------

class TestChooseMany:
    @pytest.mark.parametrize("name", sorted(SPECS))
    def test_agrees_with_choose(self, builds, name):
        driver = builds[name].driver
        cols = lattice(ENVELOPES[name])
        cfgs, ok = driver.choose_many(cols)
        for i, D in enumerate(_rows(driver, cols)):
            driver.namespace["_HISTORY"].clear()
            ref = driver.choose(D)
            assert bool(ok[i]), (name, D)
            assert ref == {p: int(cfgs[p][i])
                           for p in driver.program_params}, (name, D)

    @pytest.mark.parametrize("margin", [0.0, 0.1])
    def test_margin_tiebreak_agrees(self, builds, margin):
        # A widened margin exercises the pipeline-buffers/grid-steps
        # tie-break over many near-optimal rows; agreement must hold there
        # too, not just at the argmin.
        driver = builds["matmul"].driver
        cols = lattice(ENVELOPES["matmul"])
        cfgs, ok = driver.choose_many(cols, margin=margin)
        for i, D in enumerate(_rows(driver, cols)):
            driver.namespace["_HISTORY"].clear()
            assert driver.choose(D, margin=margin) == {
                p: int(cfgs[p][i]) for p in driver.program_params}

    def test_infeasible_shapes_flagged(self, builds):
        driver = builds["matmul"].driver
        # k=1: every bk candidate (>=128) exceeds the padded data extent.
        cols = {"m": np.array([1024, 1024]), "n": np.array([1024, 1024]),
                "k": np.array([1024, 1])}
        cfgs, ok = driver.choose_many(cols)
        assert list(ok) == [True, False]
        assert all(int(cfgs[p][1]) == 0 for p in driver.program_params)
        with pytest.raises(ValueError):
            driver.choose({"m": 1024, "n": 1024, "k": 1})

    def test_fills_decision_history(self, builds):
        driver = builds["matmul"].driver
        driver.namespace["_HISTORY"].clear()
        cols = lattice(ENVELOPES["matmul"])
        cfgs, ok = driver.choose_many(cols)
        assert len(driver.namespace["_HISTORY"]) == int(ok.sum())
        # choose() now serves from the memo: break estimate() to prove no
        # re-evaluation happens.
        driver.namespace["estimate"] = None
        try:
            D = _rows(driver, cols)[0]
            assert driver.choose(D) == {p: int(cfgs[p][0])
                                        for p in driver.program_params}
        finally:
            del driver.namespace["estimate"]
            exec(compile(driver.source, "<d>", "exec"), driver.namespace)

    def test_legacy_driver_fallback(self, builds):
        """A cached artifact generated before choose_many existed degrades
        to a per-shape loop with identical results."""
        modern = builds["flash"].driver
        ns = dict(modern.namespace)
        ns.pop("choose_many")
        legacy = DriverProgram(kernel=modern.kernel, source=modern.source,
                               namespace=ns, hw=modern.hw)
        cols = lattice(ENVELOPES["flash"])
        got, ok_l = legacy.choose_many(cols)
        want, ok_m = modern.choose_many(cols)
        assert list(ok_l) == list(ok_m)
        for p in modern.program_params:
            assert list(got[p]) == list(want[p])

    def test_scalar_broadcast(self, builds):
        driver = builds["ssd"].driver
        cfgs, ok = driver.choose_many(
            {"bh": 8, "s": np.array([1024, 2048, 4096]), "chunkflops": 1})
        assert ok.shape == (3,) and ok.all()


# ---------------------------------------------------------------------------
# LaunchPlanTable: packed keys, open addressing, persistence
# ---------------------------------------------------------------------------

class TestLaunchPlanTable:
    def _table(self, n=64, tuning_version=3):
        rng = np.random.RandomState(0)
        shapes = {"a": rng.randint(1, 1 << 40, n),
                  "b": rng.randint(1, 1 << 20, n)}
        configs = {"x": rng.randint(8, 1024, n),
                   "y": rng.randint(8, 1024, n)}
        table = LaunchPlanTable.build(
            "k", V5E.name, ("a", "b"), ("x", "y"), shapes, configs,
            tuning_version=tuning_version, source_hash="abc123")
        return table, shapes, configs

    def test_lookup_hit_and_miss(self):
        table, shapes, configs = self._table()
        for i in range(len(shapes["a"])):
            D = {"a": int(shapes["a"][i]), "b": int(shapes["b"][i])}
            assert table.lookup(D) == {"x": int(configs["x"][i]),
                                       "y": int(configs["y"][i])}
        assert table.lookup({"a": 123456789, "b": 42}) is None
        assert table.lookup({"a": 1}) is None          # missing data param

    def test_load_factor_and_entry_count(self):
        table, *_ = self._table(n=100)
        assert len(table) == 100
        assert table.hashes.shape[0] >= 200        # load factor <= 0.5
        assert len(table.entries()) == 100

    def test_duplicate_shape_last_wins(self):
        table = LaunchPlanTable.build(
            "k", V5E.name, ("a",), ("x",),
            {"a": np.array([7, 7])}, {"x": np.array([8, 512])})
        assert len(table) == 1
        assert table.lookup({"a": 7}) == {"x": 512}

    def test_ok_mask_drops_rows(self):
        table = LaunchPlanTable.build(
            "k", V5E.name, ("a",), ("x",),
            {"a": np.array([1, 2, 3])}, {"x": np.array([10, 20, 30])},
            ok=np.array([True, False, True]))
        assert len(table) == 2
        assert table.lookup({"a": 2}) is None

    def test_json_roundtrip(self):
        table, shapes, configs = self._table()
        clone = LaunchPlanTable.from_json(
            json.loads(json.dumps(table.to_json())))
        assert clone.tuning_version == table.tuning_version
        assert clone.source_hash == "abc123"
        assert clone.data_params == table.data_params
        assert len(clone) == len(table)
        for i in range(len(shapes["a"])):
            D = {"a": int(shapes["a"][i]), "b": int(shapes["b"][i])}
            assert clone.lookup(D) == table.lookup(D)

    def test_pack_shape_stable_and_positive(self):
        assert pack_shape((4096, 4096, 512)) == pack_shape((4096, 4096, 512))
        assert pack_shape((4096, 4096, 512)) != pack_shape((4096, 512, 4096))
        for v in [(0,), (1 << 62, 1 << 62), (2**40, 3, 5)]:
            assert 0 <= pack_shape(v) < 2 ** 63


# ---------------------------------------------------------------------------
# Dispatch integration: choose_or_default consults the plan first
# ---------------------------------------------------------------------------

class TestDispatch:
    def _install(self, builds, name="matmul", register_driver=True):
        from repro.core import register_driver as reg
        driver = builds[name].driver
        if register_driver:
            reg(driver)
        plan = compile_plan(driver, lattice(ENVELOPES[name]))
        registry.register_plan(plan)
        return driver, plan

    def test_plan_source_and_config(self, clean, builds):
        driver, plan = self._install(builds)
        D = {"m": 1024, "n": 2048, "k": 512}
        events = []
        set_choice_listener(events.append)
        cfg = choose_or_default(driver.kernel, D, {"bm": -1})
        assert events[-1].source == "plan"
        driver.namespace["_HISTORY"].clear()
        assert cfg == driver.choose(D)
        assert registry.stats()["plan_hits"] == 1

    def test_plan_serves_without_driver(self, clean, builds):
        """A plan artifact alone (no compiled driver anywhere) dispatches."""
        driver, plan = self._install(builds, register_driver=False)
        assert registry.get(driver.kernel) is None
        D = {"m": 1024, "n": 2048, "k": 512}
        cfg = choose_or_default(driver.kernel, D, {"bm": -1})
        assert cfg == plan.lookup(D)
        assert cfg != {"bm": -1}

    def test_lazy_fill_outside_envelope(self, clean, builds):
        # The decision memo would serve the repeat before the plan probe;
        # pin it off -- this test is about the registry's lazy-fill path
        # (which still backs every first-per-generation decision).
        from repro.core import set_decision_memo
        prev = set_decision_memo(False)
        try:
            driver, _ = self._install(builds)
            D = {"m": 96, "n": 384, "k": 640}        # not a lattice point
            events = []
            set_choice_listener(events.append)
            first = choose_or_default(driver.kernel, D, {"bm": -1})
            second = choose_or_default(driver.kernel, D, {"bm": -1})
            assert [e.source for e in events] == ["driver", "plan"]
            assert first == second
            stats = registry.stats()
            assert stats["plan_misses"] == 1 and stats["plan_hits"] == 1
        finally:
            set_decision_memo(prev)

    def test_override_outranks_plan(self, clean, builds):
        driver, _ = self._install(builds)
        D = {"m": 1024, "n": 2048, "k": 512}
        pinned = {"bm": 8, "bn": 128, "bk": 128}
        registry.note_override(driver.kernel, driver.hw.name, D, pinned)
        assert choose_or_default(driver.kernel, D, {"bm": -1}) == pinned

    def test_invalidate_kernel_drops_plan_and_fills(self, clean, builds):
        driver, _ = self._install(builds)
        D_out = {"m": 96, "n": 384, "k": 640}
        choose_or_default(driver.kernel, D_out, {"bm": -1})    # lazy fill
        registry.invalidate_kernel(driver.kernel)
        assert registry.plan(driver.kernel, driver.hw.name) is None
        # With plan, fills, and driver gone, dispatch is the default again.
        assert choose_or_default(driver.kernel, D_out,
                                 {"bm": -1}) == {"bm": -1}

    def test_new_driver_generation_drops_plan(self, clean, builds):
        """Registering a *different* driver retires the plan (it is frozen
        output of the old one); re-registering the same module keeps it."""
        from repro.core import register_driver as reg
        driver, plan = self._install(builds)
        reg(driver)                                   # same source: kept
        assert registry.plan(driver.kernel, driver.hw.name) is plan
        other = DriverProgram.from_source(
            driver.kernel, driver.source + "\n# refit\n", driver.hw,
            tuning_version=1)
        reg(other)                                    # new generation
        assert registry.plan(driver.kernel, driver.hw.name) is None

    def test_stale_fill_rejected_after_hot_swap(self, clean, builds):
        """A config computed by the pre-refit driver must not be pinned
        into a plan compiled from the post-refit driver (the race window
        when a concurrent hot-swap lands between choose and the fill)."""
        driver, _ = self._install(builds)
        D = {"m": 96, "n": 384, "k": 640}
        old_cfg = {"bm": 8, "bn": 128, "bk": 128}
        registry.note_plan_fill(driver.kernel, driver.hw.name, D, old_cfg,
                                source_hash="stale-generation")
        assert registry.plan_lookup(driver.kernel, driver.hw.name, D) is None
        # the same fill from the plan's own driver is accepted
        registry.note_plan_fill(driver.kernel, driver.hw.name, D, old_cfg,
                                source_hash=driver.source_hash)
        assert registry.plan_lookup(driver.kernel, driver.hw.name,
                                    D) == old_cfg

    def test_choose_many_counters(self, clean, builds):
        driver = builds["ssd"].driver
        driver.choose_many(lattice(ENVELOPES["ssd"]))
        stats = registry.stats()
        assert stats["choose_many_calls"] == 1
        assert stats["choose_many_rows"] == 6


# ---------------------------------------------------------------------------
# Plan artifacts: the new cache entry kind + fleet warm start
# ---------------------------------------------------------------------------

class TestPlanCache:
    def _entry(self, version=0, key="p" * 64):
        table = LaunchPlanTable.build(
            "k", V5E.name, ("a",), ("x",),
            {"a": np.array([64, 128])}, {"x": np.array([8, 16])},
            tuning_version=version)
        return PlanEntry(kernel="k", key=key, hw_name=V5E.name,
                         plan=table.to_json(), created_at=1.0,
                         tuning_version=version)

    def test_put_get_roundtrip(self, clean):
        cache = DriverCache()
        cache.put_plan(self._entry())
        entry = cache.get_plan("k", "p" * 64)
        assert entry is not None
        assert LaunchPlanTable.from_json(entry.plan).lookup(
            {"a": 128}) == {"x": 16}

    def test_lookup_latest_prefers_generation(self, clean):
        cache = DriverCache()
        cache.put_plan(self._entry(version=0, key="a" * 64))
        cache.put_plan(self._entry(version=2, key="b" * 64))
        assert cache.lookup_latest_plan("k", V5E.name).tuning_version == 2

    def test_tampered_plan_evicted(self, clean):
        cache = DriverCache()
        path = cache.put_plan(self._entry())
        raw = json.load(open(path))
        raw["tuning_version"] = 99
        json.dump(raw, open(path, "w"))
        assert cache.get_plan("k", "p" * 64) is None

    def test_invalidate_below_version_evicts_plans(self, clean):
        cache = DriverCache()
        for v, key in ((0, "a" * 64), (1, "b" * 64)):
            cache.put_plan(self._entry(version=v, key=key))
        removed = cache.invalidate("k", V5E.name, below_version=1)
        assert removed == 1
        assert cache.get_plan("k", "a" * 64) is None
        assert cache.get_plan("k", "b" * 64) is not None

    def test_plan_files_invisible_to_driver_lookup(self, clean):
        cache = DriverCache()
        cache.put_plan(self._entry())
        assert cache.lookup_latest("k", V5E.name) is None


class TestFleetWarmStart:
    def _build_cached(self):
        sim = V5eSimulator(noise=0.03, seed=5)
        kl = Klaraptor(sim)
        return kl.build_driver(matmul_spec(), repeats=2,
                               max_configs_per_size=16, register=True)

    def test_precompile_then_fleet_load(self, clean):
        build = self._build_cached()
        axes = ENVELOPES["matmul"]
        first = precompile_plans({build.driver.kernel: axes})
        assert first["compiled"] == [build.driver.kernel]
        assert first["entries"] == len(
            registry.plan(build.driver.kernel, V5E.name))

        # "Second process": fresh registry, everything through artifacts.
        registry.clear()
        summary = warm_start_from_cache()
        assert summary == [build.driver.kernel]
        assert summary.plans_loaded == [build.driver.kernel]
        second = precompile_plans({build.driver.kernel: axes})
        assert second["loaded"] == [build.driver.kernel]   # no recompile
        D = {"m": 1024, "n": 2048, "k": 512}
        events = []
        set_choice_listener(events.append)
        choose_or_default(build.driver.kernel, D, {"bm": -1})
        assert events[-1].source == "plan"

    def test_lazy_read_through_installs_plan(self, clean):
        """A fresh process that never calls warm_start_from_cache still
        gets O(1) dispatch: get_driver's disk read-through installs the
        persisted plan compiled from the driver it just loaded."""
        build = self._build_cached()
        precompile_plans({build.driver.kernel: ENVELOPES["matmul"]})
        registry.clear()
        events = []
        set_choice_listener(events.append)
        cfg = choose_or_default(build.driver.kernel,
                                {"m": 1024, "n": 2048, "k": 512}, {"bm": -1})
        assert events[-1].source == "plan"
        assert cfg != {"bm": -1}

    def test_precompile_skips_untuned_kernel(self, clean):
        summary = precompile_plans({"nonexistent_kernel": {"m": [8]}})
        assert summary["skipped"] == ["nonexistent_kernel"]
        assert summary["entries"] == 0

    def test_precompile_survives_unwritable_cache(self, clean, builds,
                                                  tmp_path, monkeypatch,
                                                  caplog):
        """A read-only serving node still compiles and serves its plans;
        persistence is best-effort (one warning, no crash)."""
        import logging

        import repro.core.plan as plan_mod
        blocker = tmp_path / "blocker"
        blocker.write_text("")
        monkeypatch.setenv("KLARAPTOR_CACHE_DIR", str(blocker / "sub"))
        monkeypatch.setattr(plan_mod, "_plan_write_warned", False)
        from repro.core import register_driver
        register_driver(builds["matmul"].driver)
        with caplog.at_level(logging.WARNING, logger="repro.core.plan"):
            summary = precompile_plans(
                {"matmul_b16": ENVELOPES["matmul"]})
        assert summary["compiled"] == ["matmul_b16"]
        assert registry.plan("matmul_b16", V5E.name) is not None
        assert any("plan artifact write failed" in r.message
                   for r in caplog.records)

    def test_warm_start_summary_counts(self, clean):
        summary = warm_start_from_cache()
        assert summary == [] and summary.plans_loaded == []
        build = self._build_cached()          # registered + cached
        summary = warm_start_from_cache()
        assert summary == [] and summary.already_registered == 1
        registry.clear()
        summary = warm_start_from_cache(
            [build.driver.kernel, "missing_kernel"])
        assert list(summary) == [build.driver.kernel]
        assert summary.skipped_no_entry == 1
        assert set(summary.as_dict()) == {
            "loaded", "plans_loaded", "already_registered",
            "skipped_no_entry", "skipped_bad"}

    def test_stale_plan_not_loaded_for_new_driver(self, clean):
        """A persisted plan from an older driver generation is not
        installed next to the newer driver it does not describe."""
        build = self._build_cached()
        plan = compile_plan(build.driver, lattice(ENVELOPES["matmul"]))
        stale = LaunchPlanTable.from_json(
            {**plan.to_json(), "source_hash": "deadbeef"})
        cache = DriverCache()
        cache.put_plan(PlanEntry(
            kernel=build.driver.kernel, key="s" * 64, hw_name=V5E.name,
            plan=stale.to_json(), created_at=1.0))
        registry.clear()
        summary = warm_start_from_cache()
        assert summary == [build.driver.kernel]
        assert summary.plans_loaded == []


# ---------------------------------------------------------------------------
# Telemetry surface: plan metrics in JSON and Prometheus output
# ---------------------------------------------------------------------------

class TestPlanMetrics:
    def test_exporter_reports_plan_counters(self, clean, builds):
        from repro.telemetry import Telemetry
        driver = builds["matmul"].driver
        from repro.core import register_driver
        register_driver(driver)
        registry.register_plan(compile_plan(driver,
                                            lattice(ENVELOPES["matmul"])))
        tel = Telemetry([matmul_spec()], V5eSimulator(seed=0), cache=False)
        with tel:
            choose_or_default(driver.kernel, {"m": 1024, "n": 2048,
                                              "k": 512}, {"bm": -1})
        snap = tel.snapshot()
        assert snap["counters"]["choices_by_source"] == {"plan": 1}
        assert snap["counters"]["plan_hits"] == 1
        assert snap["counters"]["choose_many_calls"] >= 1
        assert snap["counters"]["choose_many_rows"] >= 1
        prom = tel.prometheus()
        assert 'klaraptor_choices_total{source="plan"} 1' in prom
        assert "klaraptor_plan_hits 1" in prom
        assert "klaraptor_choose_many_calls" in prom


# ---------------------------------------------------------------------------
# Satellites: D-specialization of rational programs, _fit_tile memo
# ---------------------------------------------------------------------------

class TestSpecialize:
    def test_expr_folding_matches_eval(self):
        from repro.core import ceil_div, var
        e = ceil_div(var("m"), var("bm")) * ceil_div(var("n"), var("bn"))
        s = e.specialize({"m": 4096, "n": 2048})
        assert s.free_vars() == {"bm", "bn"}
        env = {"bm": np.array([8.0, 128.0]), "bn": np.array([128.0, 256.0])}
        np.testing.assert_array_equal(
            s.eval(env), e.eval({**env, "m": 4096, "n": 2048}))

    def test_select_folds_and_pieces_shrink(self):
        from repro.core import RationalProgram, Select, const, var
        e = Select(var("d") >= const(128), var("p") * 2.0, var("p") * 3.0)
        prog = RationalProgram("t", ("d", "p"), {"E": e})
        assert prog.count_pieces() == 2
        spec = prog.specialize({"d": 256})
        assert spec.count_pieces() == 1           # decision node folded away
        assert spec.inputs == ("p",)
        assert float(spec.eval({"p": 5.0})) == 10.0

    def test_full_binding_gives_constant(self):
        from repro.core import Const, var
        e = (var("a") + var("b")) / var("c")
        s = e.specialize({"a": 6, "b": 2, "c": 4})
        assert isinstance(s, Const) and s.value == 2.0

    def test_partially_bound_fitted_leaf(self, builds):
        """Specializing a program whose Fitted leaves mix D and P must pin
        the D inputs (partial application) so the specialized program is
        evaluable with only its advertised inputs."""
        from repro.core import (Fitted, RationalProgram, build_time_program,
                                matmul_spec, var)
        fits = {m: f.function for m, f in builds["matmul"].fits.items()}
        prog = build_time_program(matmul_spec(), fits)
        D = {"m": 4096.0, "n": 2048.0, "k": 1024.0}
        sp = prog.specialize(D)
        assert not (set(sp.inputs) & set(D))
        P = {"bm": np.array([128.0, 256.0]), "bn": np.array([512.0, 512.0]),
             "bk": np.array([512.0, 1024.0])}
        np.testing.assert_allclose(sp.eval(P), prog.eval({**D, **P}))
        # a partially-applied leaf refuses source emission (codegen never
        # produces one; silently wrong source would be worse)
        leaf = Fitted("g", fits["mem_step"], {"bm": 8.0})
        with pytest.raises(NotImplementedError):
            leaf.to_source()


class TestFitTileMemo:
    def test_memoized_and_correct(self):
        from repro.kernels.ops import _fit_tile
        _fit_tile.cache_clear()
        raw = _fit_tile.__wrapped__
        cases = [(4096, 512, 128), (100, 64, 8), (7, 512, 8),
                 (384, 512, 128), (4096, 512, 128)]
        for size, tile, align in cases:
            assert _fit_tile(size, tile, align) == raw(size, tile, align)
        info = _fit_tile.cache_info()
        assert info.hits >= 1 and info.misses == len(set(cases))
