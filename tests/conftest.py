"""Shared test fixtures.

NOTE: no XLA_FLAGS here -- smoke tests and benches must see 1 device
(the dry-run sets its own flags in its own process).
"""

import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")


@pytest.fixture(scope="session", autouse=True)
def _isolated_driver_cache(tmp_path_factory):
    """Point the persistent driver-artifact cache at a session-local tmp dir
    so tests never read or pollute the user's ~/.cache/klaraptor."""
    d = tmp_path_factory.mktemp("klaraptor-cache")
    old = os.environ.get("KLARAPTOR_CACHE_DIR")
    os.environ["KLARAPTOR_CACHE_DIR"] = str(d)
    yield str(d)
    if old is None:
        os.environ.pop("KLARAPTOR_CACHE_DIR", None)
    else:
        os.environ["KLARAPTOR_CACHE_DIR"] = old


def run_with_devices(code: str, n_devices: int = 8, timeout: int = 240) -> str:
    """Run a python snippet in a subprocess with fake XLA devices.

    Multi-device tests must not pollute this process's jax device state.
    Raises on failure; returns stdout.
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=timeout, env=env)
    if proc.returncode != 0:
        raise AssertionError(
            f"subprocess failed:\nSTDOUT:\n{proc.stdout}\n"
            f"STDERR:\n{proc.stderr}")
    return proc.stdout


@pytest.fixture(scope="session")
def devices8():
    return lambda code, timeout=240: run_with_devices(code, 8, timeout)
