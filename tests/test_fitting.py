"""Property tests (hypothesis) + unit tests for the SVD rational fitter."""

import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis (pip install -r requirements-dev.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import fit_auto, fit_polynomial, fit_rational

SETTINGS = dict(max_examples=25, deadline=None)


def _rand_domain(rng, n, v):
    return rng.uniform(1.0, 64.0, size=(n, v))


class TestExactRecovery:
    @settings(**SETTINGS)
    @given(st.integers(0, 10_000))
    def test_recovers_random_rational_function(self, seed):
        """Noiseless samples of p/q with known degree bounds are recovered
        (to relative error ~ numerical noise) by the SVD fit -- the paper's
        'if the values were known exactly ... determined exactly via
        rational function interpolation'."""
        rng = np.random.RandomState(seed)
        v = rng.randint(1, 3)
        num_c = rng.uniform(-3, 3, size=(v + 1,))
        den_c = rng.uniform(0.5, 2.0, size=(v + 1,))
        X = _rand_domain(rng, 120, v)

        def f(X):
            num = num_c[0] + X @ num_c[1:]
            den = den_c[0] + X @ den_c[1:]
            return num / den

        y = f(X)
        res = fit_rational(X, y, [f"x{i}" for i in range(v)],
                           (1,) * v, (1,) * v)
        assert res is not None
        pred = res.function(X)
        rel = np.abs(pred - y) / np.maximum(np.abs(y), 1e-9)
        assert np.median(rel) < 1e-6

    @settings(**SETTINGS)
    @given(st.integers(0, 10_000))
    def test_recovers_polynomial(self, seed):
        rng = np.random.RandomState(seed)
        coefs = rng.uniform(-2, 2, size=3)
        X = _rand_domain(rng, 60, 1)
        y = coefs[0] + coefs[1] * X[:, 0] + coefs[2] * X[:, 0] ** 2
        res = fit_polynomial(X, y, ("x",), (2,))
        assert res.rel_error < 1e-8

    def test_extrapolation(self):
        """Fit at small sizes, predict at 8x larger -- the paper's central
        usage pattern (probe small N, choose configs at large N)."""
        rng = np.random.RandomState(3)
        X = rng.uniform(32, 256, size=(150, 2))
        f = lambda X: (5.0 * X[:, 0] * X[:, 1] + X[:, 0]) / (1.0 + 0.01 * X[:, 1])
        res = fit_auto(X, f(X), ("a", "b"), max_num_degree=2,
                       max_den_degree=1)
        Xbig = rng.uniform(1024, 2048, size=(50, 2))
        rel = np.abs(res.function(Xbig) - f(Xbig)) / np.abs(f(Xbig))
        assert np.median(rel) < 0.05


class TestNoiseRobustness:
    @settings(**SETTINGS)
    @given(st.integers(0, 10_000))
    def test_fit_under_lognormal_noise(self, seed):
        """With multiplicative profiling noise the median relative error of
        the fit stays comparable to the noise level (no blow-up from
        ill-conditioning -- the reason the paper uses SVD)."""
        rng = np.random.RandomState(seed)
        X = _rand_domain(rng, 200, 2)
        clean = 2.0 + 0.5 * X[:, 0] + 0.1 * X[:, 0] * X[:, 1]
        y = clean * np.exp(rng.normal(0, 0.05, size=clean.shape))
        res = fit_auto(X, y, ("a", "b"), max_num_degree=2, max_den_degree=1)
        rel = np.abs(res.function(X) - clean) / np.abs(clean)
        assert np.median(rel) < 0.15

    def test_pole_rejection(self):
        """Candidates whose denominator changes sign on the domain must be
        rejected (extrapolation through a pole is meaningless)."""
        rng = np.random.RandomState(0)
        X = rng.uniform(1, 10, size=(80, 1))
        y = 1.0 / (X[:, 0] - 5.0)     # true pole inside the domain
        res = fit_rational(X, y, ("x",), (1,), (1,))
        assert res is None or res.function.denominator_sign_stable(X)


class TestModelSelection:
    def test_auto_prefers_small_models_for_simple_data(self):
        rng = np.random.RandomState(1)
        X = _rand_domain(rng, 100, 1)
        y = 3.0 * X[:, 0] + 1.0
        res = fit_auto(X, y, ("x",), max_num_degree=3, max_den_degree=2)
        assert res.rel_error < 1e-6
        assert res.n_params <= 6   # parsimony: no runaway degree

    def test_underdetermined_skipped(self):
        X = np.array([[1.0], [2.0], [3.0]])
        y = np.array([1.0, 2.0, 3.0])
        res = fit_rational(X, y, ("x",), (3,), (3,))
        assert res is None  # 8 params from 3 samples: refused
