"""Dry-run machinery tests (scaled-down mesh in a subprocess)."""

import json

import pytest

from repro.configs import ARCH_IDS, SHAPES, get_config, shape_applicable


class TestShapeApplicability:
    def test_long_500k_only_for_subquadratic(self):
        runnable = [a for a in ARCH_IDS
                    if shape_applicable(get_config(a), "long_500k")[0]]
        assert sorted(runnable) == ["jamba-1.5-large-398b", "mamba2-130m"]

    def test_cell_count(self):
        cells = 0
        for a in ARCH_IDS:
            for s in SHAPES:
                if shape_applicable(get_config(a), s)[0]:
                    cells += 1
        assert cells == 32   # 10 archs x 4 shapes - 8 long_500k skips

    def test_input_specs_cover_all_inputs(self):
        import jax
        from repro.launch.steps import input_specs
        for arch in ("llama3.2-1b", "internvl2-76b", "whisper-medium",
                     "mamba2-130m"):
            cfg = get_config(arch, smoke=True)
            for name, preset in SHAPES.items():
                if not shape_applicable(cfg, name)[0]:
                    continue
                specs = input_specs(cfg, preset)
                for leaf in jax.tree.leaves(specs):
                    assert isinstance(leaf, jax.ShapeDtypeStruct)
                if preset.kind == "decode":
                    assert "cache" in specs
                elif cfg.arch_kind == "vlm":
                    assert "patches" in specs
                elif cfg.arch_kind == "encdec":
                    assert "frames" in specs


@pytest.mark.slow
class TestDryRunCell(object):
    def test_lower_compile_and_analyze_small_mesh(self, devices8):
        out = devices8("""
            import os, json
            import jax
            from repro.analysis.hlo import collective_bytes
            from repro.configs import get_config
            from repro.configs.base import ShapePreset
            from repro.launch.mesh import make_mesh
            from repro.launch.steps import build_step

            cfg = get_config("llama3.2-1b", smoke=True)
            mesh = make_mesh((2, 4), ("data", "model"))
            preset = ShapePreset("t", "train", 128, 8)
            bundle = build_step(cfg, preset, mesh)
            with mesh:
                lowered = bundle.lower()
                compiled = lowered.compile()
            mem = compiled.memory_analysis()
            ca = compiled.cost_analysis()
            if isinstance(ca, (list, tuple)):   # older jax returns a list
                ca = ca[0]
            st = collective_bytes(compiled.as_text())
            assert ca["flops"] > 0
            assert mem.temp_size_in_bytes > 0
            assert st.total_wire_bytes > 0   # sharded step must communicate
            print("ok", ca["flops"], st.total_wire_bytes)
        """, timeout=420)
        assert "ok" in out

    def test_multi_pod_axis_shards(self, devices8):
        out = devices8("""
            import jax
            from repro.configs import get_config
            from repro.configs.base import ShapePreset
            from repro.launch.mesh import make_mesh
            from repro.launch.steps import build_step

            cfg = get_config("llama3.2-1b", smoke=True)
            mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
            preset = ShapePreset("t", "train", 128, 8)
            bundle = build_step(cfg, preset, mesh)
            with mesh:
                compiled = bundle.lower().compile()
            # tokens (8, 129): batch must shard over pod*data = 4
            tok_sh = bundle.in_shardings[2]["tokens"]
            spec = tok_sh.spec
            assert spec[0] == ("pod", "data"), spec
            print("ok")
        """, timeout=420)
        assert "ok" in out

    def test_scan_correction_math_on_real_records(self):
        from repro.launch.dryrun import corrected_costs
        rec = {"full": {"flops": 50.0, "bytes": 10.0,
                        "collective_wire_bytes_per_device": 1.0},
               "diff": {"groups": 10,
                        "g1": {"flops": 15.0, "bytes": 2.0,
                               "collective_wire_bytes_per_device": 0.2},
                        "g2": {"flops": 20.0, "bytes": 3.0,
                               "collective_wire_bytes_per_device": 0.3}}}
        out = corrected_costs(rec)
        assert out["flops"] == pytest.approx(10 + 5 * 10)   # base + pg*G
        assert out["bytes"] == pytest.approx(1 + 1 * 10)
        # clamped from below by the full-depth compile's own measurement
        rec["full"]["flops"] = 100.0
        assert corrected_costs(rec)["flops"] == pytest.approx(100.0)
