"""Per-arch smoke tests (assignment requirement): reduced config, one
forward/train step on CPU, output shapes + no NaNs.  Plus decode-vs-forward
consistency for the stateful families."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.distributed.sharding import Sharder
from repro.models import Model
from repro.models import transformer as T

SH = Sharder(mesh=None)


def _batch(cfg, B=2, S=32):
    rng = np.random.RandomState(0)
    batch = {"tokens": jnp.asarray(
        rng.randint(0, cfg.vocab_size, size=(B, S + 1)), jnp.int32)}
    if cfg.arch_kind == "vlm":
        batch["patches"] = jnp.asarray(
            rng.randn(B, cfg.num_patches, cfg.d_model), cfg.dtype)
    elif cfg.arch_kind == "encdec":
        batch["frames"] = jnp.asarray(
            rng.randn(B, cfg.encoder_seq, cfg.d_model), cfg.dtype)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
class TestArchSmoke:
    def test_train_step_shapes_and_finite(self, arch):
        cfg = get_config(arch, smoke=True)
        model = Model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        loss, metrics = jax.jit(
            lambda p, b: model.train_loss(p, b, SH))(params, _batch(cfg))
        assert loss.shape == ()
        assert bool(jnp.isfinite(loss)), (arch, float(loss))
        # plausible initial loss for a |V|-way prediction
        assert 0.5 * np.log(cfg.vocab_size) < float(loss) < \
            2.0 * np.log(cfg.vocab_size) + 2.0, (arch, float(loss))

    def test_decode_step_shapes_and_finite(self, arch):
        cfg = get_config(arch, smoke=True)
        model = Model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        B, S = 2, 16
        cache = model.init_cache(B, S)
        logits, cache2 = jax.jit(
            lambda p, t, pos, c: model.decode_step(p, t, pos, c, SH))(
            params, jnp.array([1, 2], jnp.int32),
            jnp.zeros((B,), jnp.int32), cache)
        assert logits.shape == (B, cfg.padded_vocab)
        assert bool(jnp.all(jnp.isfinite(logits[:, :cfg.vocab_size]))), arch
        assert jax.tree.structure(cache2) == jax.tree.structure(cache)

    def test_grads_flow_everywhere(self, arch):
        cfg = get_config(arch, smoke=True)
        model = Model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        grads = jax.grad(
            lambda p: model.train_loss(p, _batch(cfg), SH)[0])(params)
        zero_leaves = []
        for path, g in jax.tree_util.tree_flatten_with_path(grads)[0]:
            if not bool(jnp.any(jnp.abs(g) > 0)):
                zero_leaves.append(jax.tree_util.keystr(path))
        # routers may have tiny-but-nonzero grads; nothing should be exactly
        # all-zero except possibly unused padding rows -- require none.
        assert not zero_leaves, (arch, zero_leaves)


class TestDecodeConsistency:
    @pytest.mark.parametrize("arch", ["llama3.2-1b", "gemma2-2b",
                                      "mamba2-130m", "jamba-1.5-large-398b"])
    def test_decode_matches_forward_f32(self, arch):
        cfg = get_config(arch, smoke=True).replace(dtype=jnp.float32)
        model = Model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        B, S = 2, 10
        toks = jnp.asarray(
            np.random.RandomState(1).randint(0, cfg.vocab_size, (B, S)))
        x = T.embed_tokens(cfg, params, toks)
        hidden, _ = T.forward(cfg, params, x, SH)
        full = T.unembed(cfg, params, hidden)
        cache = model.init_cache(B, S)
        step = jax.jit(lambda p, t, pos, c: model.decode_step(p, t, pos, c,
                                                              SH))
        errs = []
        for t in range(S):
            lg, cache = step(params, toks[:, t],
                             jnp.full((B,), t, jnp.int32), cache)
            errs.append(float(jnp.max(jnp.abs(lg - full[:, t]))))
        assert max(errs) < 2e-3, (arch, errs)


class TestConfigExactness:
    """The full configs must match the assignment table exactly."""

    def test_assigned_dims(self):
        expect = {
            "gemma2-2b": (26, 2304, 8, 4, 9216, 256000),
            "internlm2-1.8b": (24, 2048, 16, 8, 8192, 92544),
            "llama3.2-1b": (16, 2048, 32, 8, 8192, 128256),
            "qwen3-14b": (40, 5120, 40, 8, 17408, 151936),
            "jamba-1.5-large-398b": (72, 8192, 64, 8, 24576, 65536),
            "internvl2-76b": (80, 8192, 64, 8, 28672, 128256),
            "mamba2-130m": (24, 768, 1, 1, 0, 50280),
            "whisper-medium": (24, 1024, 16, 16, 4096, 51865),
            "qwen3-moe-235b-a22b": (94, 4096, 64, 4, 1536, 151936),
            "grok-1-314b": (64, 6144, 48, 8, 32768, 131072),
        }
        for arch, (L, d, h, kv, ff, v) in expect.items():
            cfg = get_config(arch)
            assert cfg.n_layers == L, arch
            assert cfg.d_model == d, arch
            assert cfg.n_heads == h, arch
            assert cfg.n_kv_heads == kv, arch
            assert cfg.d_ff == ff, arch
            assert cfg.vocab_size == v, arch

    def test_moe_configs(self):
        q = get_config("qwen3-moe-235b-a22b")
        assert (q.n_experts, q.top_k) == (128, 8)
        g = get_config("grok-1-314b")
        assert (g.n_experts, g.top_k) == (8, 2)
        j = get_config("jamba-1.5-large-398b")
        assert (j.n_experts, j.top_k) == (16, 2)
        kinds = [b.kind for b in j.block_pattern]
        assert kinds.count("attn") == 1 and kinds.count("mamba") == 7

    def test_param_counts_in_range(self):
        """Sanity: derived parameter counts land near the advertised sizes."""
        approx = {
            "gemma2-2b": (2.0e9, 3.5e9),
            "llama3.2-1b": (1.0e9, 1.6e9),
            "qwen3-14b": (12e9, 16e9),
            "jamba-1.5-large-398b": (350e9, 440e9),
            "internvl2-76b": (60e9, 85e9),
            "mamba2-130m": (0.1e9, 0.2e9),
            "qwen3-moe-235b-a22b": (200e9, 260e9),
            "grok-1-314b": (280e9, 340e9),
        }
        for arch, (lo, hi) in approx.items():
            n = Model(get_config(arch)).param_count()
            assert lo <= n <= hi, (arch, n)

    def test_sub_quadratic_flags(self):
        assert get_config("mamba2-130m").sub_quadratic
        assert get_config("jamba-1.5-large-398b").sub_quadratic
        for arch in ("gemma2-2b", "qwen3-14b", "whisper-medium",
                     "grok-1-314b"):
            assert not get_config(arch).sub_quadratic, arch
