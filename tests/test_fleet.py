"""Fleet tests: board semantics, farm determinism, faults, retune queue.

The load-bearing claim is *bit-identity*: whatever the farm does --
however work is partitioned, wherever it lands, whatever dies mid-run --
the merged dataset, the chosen configs and the cache artifacts must equal
the single-process ``collect``/``build_driver`` byte for byte.  Faults
are injected deterministically (``FaultPlan``), never mocked away.
"""

import glob
import json
import os
import threading
import time

import numpy as np
import pytest

from repro.core.cache import DriverCache
from repro.core.collect import default_probe_data
from repro.core.device_model import V5E, V5eSimulator
from repro.core.tuner import Klaraptor
from repro.fleet import (FaultPlan, FleetConfig, FleetCoordinator, JobBoard,
                         RetuneQueue, SpecRef, WallClockSim, collected_equal,
                         device_from_json, device_to_json, execute_job,
                         job_key, make_job, tier1_spec_refs)
from repro.fleet.queue import drift_key

SEED = 3
N_CFG = 6


def _pd(spec, n=2):
    return default_probe_data(spec)[:n]


def _device():
    return V5eSimulator(V5E, noise=0.04, seed=7)


def _artifacts(cache_root):
    return sorted(os.path.basename(p) for p in glob.glob(
        os.path.join(cache_root, "**", "*.json"), recursive=True))


def _drift_line(**over):
    d = {"type": "drift", "kernel": "matmul_b16", "hw": "tpu_v5e",
         "bucket": "m=1024|k=512|n=512",
         "D": {"m": 1024, "k": 512, "n": 512},
         "config": {"bm": 512, "bn": 256, "bk": 256},
         "rel_error_ewma": 0.4, "n_samples": 9,
         "predicted_s": 1e-3, "observed_s": 1.4e-3}
    d.update(over)
    return d


class TestJobsAndKeys:
    def test_job_key_canonical(self):
        a = job_key("batch", {"x": 1, "y": [2, 3]})
        b = job_key("batch", {"y": [2, 3], "x": 1})
        assert a == b and len(a) == 64
        assert job_key("batch", {"x": 2, "y": [2, 3]}) != a
        assert job_key("kernel", {"x": 1, "y": [2, 3]}) != a

    def test_make_job_normalizes_payload(self):
        j1 = make_job("batch", {"D": {"m": np.int64(256)}})
        j2 = make_job("batch", {"D": {"m": 256}})
        assert j1.key == j2.key

    def test_spec_ref_roundtrip(self):
        for name, ref in tier1_spec_refs().items():
            back = SpecRef.from_json(ref.to_json())
            assert back.build().name == ref.build().name == name

    def test_device_roundtrip_same_fingerprint(self):
        dev = _device()
        back = device_from_json(device_to_json(dev))
        assert back.fingerprint() == dev.fingerprint()

    def test_wallclock_sim_transparent(self):
        inner = _device()
        wc = WallClockSim(inner, scale=0.0)
        # identical cache identity and identical probe bytes
        assert wc.fingerprint() == inner.fingerprint()
        spec = tier1_spec_refs()["matmul_b16"].build()
        D = _pd(spec)[0]
        table = spec.candidates(D, V5E)
        tt = spec.traffic_table(D, table, V5E)
        idx = np.arange(min(4, len(table)))
        reps = np.full(idx.shape, 2, dtype=np.int64)
        p1 = inner.probe_rows(tt.select(idx), np.random.RandomState(0), reps)
        p2 = wc.probe_rows(tt.select(idx), np.random.RandomState(0), reps)
        np.testing.assert_array_equal(p1.total_time_s, p2.total_time_s)

    def test_wallclock_sim_beats_while_sleeping(self):
        beats = []
        wc = WallClockSim(_device(), scale=0.5, beat=lambda: beats.append(1),
                          slice_s=0.01)
        wc._sleep(0.05)
        assert len(beats) >= 4


class TestJobBoard:
    def _job(self, n=0):
        return make_job("batch", {"n": n})

    def test_claim_is_exclusive(self, tmp_path):
        board = JobBoard(tmp_path / "spool")
        job = self._job()
        assert board.submit(job) == "jobs"
        doc = board.claim("w0")
        assert doc is not None and doc["key"] == job.key
        assert board.claim("w1") is None

    def test_submit_dedups_against_every_stage(self, tmp_path):
        board = JobBoard(tmp_path / "spool")
        job = self._job()
        board.submit(job)
        assert board.submit(job) == "jobs"
        board.claim("w0")
        assert board.submit(job) == "claimed"
        board.complete(job.key, "w0", {"ok": True})
        assert board.submit(job) == "results"
        assert board.counts() == {"jobs": 0, "claimed": 0, "results": 1,
                                  "failed": 0}

    def test_duplicate_result_dropped_not_merged(self, tmp_path):
        board = JobBoard(tmp_path / "spool")
        job = self._job()
        board.submit(job)
        board.claim("w0")
        assert board.complete(job.key, "w0", {"v": "first"}) is True
        assert board.complete(job.key, "w1", {"v": "second"}) is False
        assert board.result(job.key)["v"] == "first"

    def test_fail_requeues_then_parks(self, tmp_path):
        board = JobBoard(tmp_path / "spool", max_attempts=2)
        job = self._job()
        board.submit(job)
        board.claim("w0")
        assert board.fail(job.key, "w0", "boom1") == "jobs"
        board.claim("w1")
        assert board.fail(job.key, "w1", "boom2") == "failed"
        doc = board.failure(job.key)
        assert doc["attempts"] == 2
        assert [e["error"] for e in doc["errors"]] == ["boom1", "boom2"]

    def test_requeue_stale_expires_only_old_leases(self, tmp_path):
        board = JobBoard(tmp_path / "spool")
        j1, j2 = self._job(1), self._job(2)
        board.submit(j1), board.submit(j2)
        board.claim("w0")
        time.sleep(0.15)
        board.claim("w1")           # fresh lease
        now = time.time()
        expired = board.requeue_stale(lease_s=0.1, now=now)
        assert expired == [min(j1.key, j2.key)] or len(expired) == 1
        # the expired one is claimable again; the fresh one is not touched
        assert board.counts()["jobs"] == 1
        assert board.counts()["claimed"] == 1

    def test_requeue_worker_reassigns_all_its_leases(self, tmp_path):
        board = JobBoard(tmp_path / "spool")
        jobs = [self._job(i) for i in range(3)]
        for j in jobs:
            board.submit(j)
        board.claim("dead"), board.claim("dead"), board.claim("alive")
        requeued = board.requeue_worker("dead", "killed in test")
        assert len(requeued) == 2
        assert board.counts() == {"jobs": 2, "claimed": 1, "results": 0,
                                  "failed": 0}

    def test_requeue_never_resurrects_completed_work(self, tmp_path):
        board = JobBoard(tmp_path / "spool")
        job = self._job()
        board.submit(job)
        board.claim("w0")
        board.complete(job.key, "w0", {"ok": True})
        assert board.requeue_worker("w0") == []
        assert board.counts()["jobs"] == 0

    def test_speculate_duplicates_lease_first_writer_wins(self, tmp_path):
        board = JobBoard(tmp_path / "spool")
        job = self._job()
        board.submit(job)
        board.claim("slow")
        assert board.speculate(job.key) is True
        assert board.speculate(job.key) is False    # already duplicated
        dup = board.claim("fast")
        assert dup["key"] == job.key                # both now hold it
        assert board.complete(job.key, "fast", {"by": "fast"}) is True
        assert board.complete(job.key, "slow", {"by": "slow"}) is False
        assert board.result(job.key)["by"] == "fast"

    def test_claim_drops_stale_duplicate_of_finished_job(self, tmp_path):
        board = JobBoard(tmp_path / "spool")
        job = self._job()
        board.submit(job)
        board.claim("slow")
        board.speculate(job.key)
        board.complete(job.key, "slow", {"ok": True})
        # the speculative copy must not be handed out after the result
        assert board.claim("fast") is None
        assert board.counts()["jobs"] == 0

    def test_stop_sentinel(self, tmp_path):
        board = JobBoard(tmp_path / "spool")
        assert not board.stop_requested()
        board.request_stop()
        assert board.stop_requested()
        board.clear_stop()
        assert not board.stop_requested()


class TestFarmDeterminism:
    """The acceptance gate: farm output == single-process output, bytes."""

    def _single(self, cache_dir, name, **kw):
        ref = tier1_spec_refs()[name]
        spec = ref.build()
        kl = Klaraptor(_device(), hw=V5E, cache=DriverCache(str(cache_dir)))
        return kl.build_driver(spec, probe_data=_pd(spec),
                               max_configs_per_size=N_CFG, seed=SEED,
                               repeats=2, **kw)

    def _assert_parity(self, sp, fb, spec):
        assert collected_equal(sp.collected, fb.collected) == []
        D = default_probe_data(spec)[-1]
        assert sp.driver.choose(D) == fb.driver.choose(D)

    def test_all_tier1_under_faults_bit_identical(self, tmp_path):
        """4 workers; one vanishes on its first job, one hangs past its
        lease (-> reassignment + a duplicate completion when it wakes).
        All four tier-1 kernels, one farm run, every byte identical."""
        refs = tier1_spec_refs()
        singles = {n: self._single(tmp_path / "c1", n) for n in refs}
        pd = {n: _pd(r.build()) for n, r in refs.items()}
        faults = {0: FaultPlan(vanish_at_job=1),
                  1: FaultPlan(hang_at_job=1, hang_s=1.5)}
        with FleetCoordinator(
                tmp_path / "spool", _device(), hw=V5E,
                cache=DriverCache(str(tmp_path / "c2")),
                config=FleetConfig(n_workers=4, lease_s=0.4,
                                   job_timeout_s=120),
                worker_faults=faults) as fc:
            out = fc.tune(refs, probe_data=pd, repeats=2,
                          max_configs_per_size=N_CFG, seed=SEED)
            stats = fc.stats
        for name, ref in refs.items():
            self._assert_parity(singles[name], out[name], ref.build())
        assert _artifacts(tmp_path / "c1") == _artifacts(tmp_path / "c2")
        # the faults actually happened and were recovered from
        assert stats.worker_deaths >= 1      # the vanished worker
        assert stats.requeues >= 1           # the hung worker's lease
        assert stats.respawns >= 1

    def test_kernel_mode_cross_size_strategy(self, tmp_path):
        name = "matmul_b16"
        sp = self._single(tmp_path / "c1", name,
                          strategy="successive_halving")
        ref = tier1_spec_refs()[name]
        with FleetCoordinator(
                tmp_path / "spool", _device(), hw=V5E,
                cache=DriverCache(str(tmp_path / "c2")),
                config=FleetConfig(n_workers=2)) as fc:
            fb = fc.tune({name: ref}, probe_data=_pd(ref.build()),
                         repeats=2, max_configs_per_size=N_CFG, seed=SEED,
                         strategy="successive_halving")[name]
            assert fc.stats.by_kind == {"kernel": 1}
        self._assert_parity(sp, fb, ref.build())
        assert _artifacts(tmp_path / "c1") == _artifacts(tmp_path / "c2")

    def test_batch_mode_refuses_cross_size_strategy(self, tmp_path):
        ref = tier1_spec_refs()["matmul_b16"]
        with FleetCoordinator(tmp_path / "spool", _device(),
                              config=FleetConfig(n_workers=0)) as fc:
            with pytest.raises(ValueError, match="cross-size state"):
                fc.tune({"matmul_b16": ref}, mode="batch",
                        strategy="successive_halving")

    def test_rows_mode_finest_grain(self, tmp_path):
        name = "matmul_b16"
        sp = self._single(tmp_path / "c1", name, shard_rows=4)
        ref = tier1_spec_refs()[name]
        with FleetCoordinator(
                tmp_path / "spool", _device(), hw=V5E,
                cache=DriverCache(str(tmp_path / "c2")),
                config=FleetConfig(n_workers=3)) as fc:
            fb = fc.tune({name: ref}, probe_data=_pd(ref.build()),
                         repeats=2, max_configs_per_size=N_CFG, seed=SEED,
                         shard_rows=4, mode="rows")[name]
            assert set(fc.stats.by_kind) == {"rows"}
            assert fc.stats.by_kind["rows"] >= 2
        self._assert_parity(sp, fb, ref.build())
        assert _artifacts(tmp_path / "c1") == _artifacts(tmp_path / "c2")

    @pytest.mark.slow
    def test_killed_process_worker_recovered(self, tmp_path):
        """A real os._exit mid-job (process backend): the lease expires,
        the job is reassigned, and the merge stays bit-identical."""
        name = "matmul_b16"
        sp = self._single(tmp_path / "c1", name)
        ref = tier1_spec_refs()[name]
        # One worker: it *must* claim the first job and die holding the
        # lease; the respawned replacement finishes everything.
        with FleetCoordinator(
                tmp_path / "spool", _device(), hw=V5E,
                cache=DriverCache(str(tmp_path / "c2")),
                config=FleetConfig(n_workers=1, backend="process",
                                   lease_s=0.5, job_timeout_s=120),
                worker_faults={0: FaultPlan(kill_at_job=1)}) as fc:
            fb = fc.tune({name: ref}, probe_data=_pd(ref.build()),
                         repeats=2, max_configs_per_size=N_CFG,
                         seed=SEED)[name]
            assert fc.stats.worker_deaths >= 1
        self._assert_parity(sp, fb, ref.build())

    def test_duplicate_execution_is_bit_identical(self):
        """The idempotence the whole design leans on: the same job
        document executes to the same bytes anywhere, any time."""
        ref = tier1_spec_refs()["matmul_b16"]
        spec = ref.build()
        job = make_job("batch", {
            "spec": ref.to_json(), "device": device_to_json(_device()),
            "hw": "tpu_v5e", "seed": SEED, "repeats": 2,
            "max_configs_per_size": N_CFG, "strategy": None,
            "max_stages": 3, "shard_rows": None,
            "D": {k: int(v) for k, v in _pd(spec)[0].items()},
            "batch_index": 0, "budget": {"max_executions": 12,
                                         "max_device_seconds": None}})
        r1 = execute_job(job.to_json())
        r2 = execute_job(job.to_json())
        assert json.dumps(r1, sort_keys=True) == \
            json.dumps(r2, sort_keys=True)


class TestRetuneQueue:
    def test_ingest_dedup_and_corrupt_counting(self, tmp_path):
        ledger = tmp_path / "flight.jsonl"
        lines = [json.dumps({"type": "choice", "kernel": "matmul_b16"}),
                 json.dumps(_drift_line()),
                 "{not json",
                 json.dumps(_drift_line(rel_error_ewma=0.6)),
                 json.dumps(_drift_line(kernel="moe_gmm_b16"))]
        ledger.write_text("\n".join(lines) + "\n")
        q = RetuneQueue(tmp_path / "state.json")
        assert q.ingest(ledger) == 2        # two distinct keys
        s = q.summary()
        assert s["pending"] == 2 and s["corrupt_lines"] == 1
        pend = dict(q.pending())
        key = drift_key(_drift_line())
        assert pend[key]["rel_error_ewma"] == 0.6   # freshest event wins
        assert q.state["pending"][key]["n_seen"] == 2

    def test_offsets_only_advance_past_complete_lines(self, tmp_path):
        ledger = tmp_path / "flight.jsonl"
        q = RetuneQueue(tmp_path / "state.json")
        with open(ledger, "w") as f:
            f.write(json.dumps(_drift_line()) + "\n")
            f.write('{"type": "drift", "kernel": "moe')   # torn mid-write
        assert q.ingest(ledger) == 1
        with open(ledger, "a") as f:        # the serving node finishes it
            f.write('_gmm_b16", "hw": "tpu_v5e", "bucket": "g=512"}\n')
        assert q.ingest(ledger) == 1        # whole line seen exactly once
        assert q.summary()["pending"] == 2
        assert q.summary()["corrupt_lines"] == 0

    def test_state_survives_restart(self, tmp_path):
        ledger = tmp_path / "flight.jsonl"
        ledger.write_text(json.dumps(_drift_line()) + "\n")
        q = RetuneQueue(tmp_path / "state.json")
        q.ingest(ledger)
        q2 = RetuneQueue(tmp_path / "state.json")   # restart
        assert q2.summary()["pending"] == 1
        assert q2.ingest(ledger) == 0               # offset persisted

    def test_done_keys_count_re_drifts_not_requeue(self, tmp_path):
        ledger = tmp_path / "flight.jsonl"
        ledger.write_text(json.dumps(_drift_line()) + "\n")
        q = RetuneQueue(tmp_path / "state.json")
        q.ingest(ledger)
        key = q.pending()[0][0]
        q.mark_done(key, {"succeeded": True})
        with open(ledger, "a") as f:
            f.write(json.dumps(_drift_line()) + "\n")
        assert q.ingest(ledger) == 0
        assert q.summary()["re_drifts"] == 1
        assert q.summary()["pending"] == 0

    def test_unreadable_state_starts_fresh(self, tmp_path):
        state = tmp_path / "state.json"
        state.write_text("{torn")
        q = RetuneQueue(state)
        assert q.summary()["pending"] == 0

    def test_priority_drain_is_traffic_weighted(self, tmp_path):
        """Drain order is drift magnitude x (1 + ledger traffic): a hot
        mildly-drifted key outranks a cold badly-drifted one."""
        ledger = tmp_path / "flight.jsonl"
        # real ledger drift lines carry bucket_label(shape_bucket(D))
        mild = _drift_line(rel_error_ewma=0.2, bucket="k9,m10,n9")
        bad = _drift_line(kernel="moe_gmm_b16", rel_error_ewma=0.5,
                          bucket="k9,m10,n9")
        ledger.write_text(json.dumps(mild) + "\n" + json.dumps(bad) + "\n")
        q = RetuneQueue(tmp_path / "state.json")
        q.ingest(ledger)
        # no traffic yet: pure magnitude, the worse fit first
        assert q.pending()[0][0] == drift_key(bad)
        # choice lines carry raw D; the tally must bucket it to the same
        # key the drift events use (bucket_label(shape_bucket(D)))
        with open(ledger, "a") as f:
            f.write(json.dumps({"type": "choice", "kernel": "matmul_b16",
                                "hw": "tpu_v5e", "D": mild["D"],
                                "n_coalesced": 10}) + "\n")
        assert q.ingest(ledger) == 0        # traffic enqueues nothing
        assert q.state["traffic"][drift_key(mild)] == 10
        assert q.priority(drift_key(mild)) == pytest.approx(0.2 * 11)
        assert q.priority(drift_key(bad)) == pytest.approx(0.5)
        assert q.pending()[0][0] == drift_key(mild)     # hot path first

    def test_choice_with_explicit_bucket_and_bare_lines(self, tmp_path):
        ledger = tmp_path / "flight.jsonl"
        lines = [{"type": "choice", "kernel": "k", "hw": "h",
                  "bucket": "m=64"},
                 {"type": "choice", "kernel": "k", "hw": "h",
                  "bucket": "m=64", "n_coalesced": 4},
                 {"type": "choice"}]        # bare line must not crash
        ledger.write_text("".join(json.dumps(e) + "\n" for e in lines))
        q = RetuneQueue(tmp_path / "state.json")
        assert q.ingest(ledger) == 0
        assert q.state["traffic"]["k|h|m=64"] == 5
        assert q.state["traffic"]["?|?|?"] == 1
        assert q.summary()["traffic_keys"] == 2

    def test_done_key_requeues_after_repeated_re_drifts(self, tmp_path):
        """One stray re-drift stays an operator decision; hitting
        ``requeue_after`` (default 2) re-enqueues the key automatically."""
        ledger = tmp_path / "flight.jsonl"
        ledger.write_text(json.dumps(_drift_line()) + "\n")
        q = RetuneQueue(tmp_path / "state.json")
        q.ingest(ledger)
        key = q.pending()[0][0]
        q.mark_done(key, {"succeeded": True})
        with open(ledger, "a") as f:        # first re-drift: counted only
            f.write(json.dumps(_drift_line()) + "\n")
        assert q.ingest(ledger) == 0
        assert q.summary()["pending"] == 0 and q.summary()["requeued"] == 0
        with open(ledger, "a") as f:        # second: the refit did not take
            f.write(json.dumps(_drift_line(rel_error_ewma=0.7)) + "\n")
        assert q.ingest(ledger) == 1
        s = q.summary()
        assert s["pending"] == 1 and s["requeued"] == 1
        assert key not in q.state["done"]
        assert dict(q.pending())[key]["rel_error_ewma"] == 0.7
        # the requeue survives a restart
        assert RetuneQueue(tmp_path / "state.json").summary()["pending"] == 1

    def test_requeue_after_one_requeues_immediately(self, tmp_path):
        ledger = tmp_path / "flight.jsonl"
        ledger.write_text(json.dumps(_drift_line()) + "\n")
        q = RetuneQueue(tmp_path / "state.json", requeue_after=1)
        q.ingest(ledger)
        key = q.pending()[0][0]
        q.mark_done(key, {"succeeded": True})
        with open(ledger, "a") as f:
            f.write(json.dumps(_drift_line()) + "\n")
        assert q.ingest(ledger) == 1
        assert q.summary()["pending"] == 1 and q.summary()["requeued"] == 1


class TestRetuneEndToEnd:
    @pytest.mark.slow
    def test_ledger_to_versioned_cache_without_touching_serving(
            self, tmp_path):
        """Drift key -> farm probe -> refit -> versioned write-through; the
        coordinator process's registry (the 'serving' side here, thanks to
        the process backend) never sees the swap."""
        from repro.core.driver import registry
        from repro.search import SearchBudget

        cache = DriverCache(str(tmp_path / "cache"))
        refs = tier1_spec_refs()
        spec = refs["matmul_b16"].build()
        kl = Klaraptor(_device(), hw=V5E, cache=cache)
        kl.build_driver(spec, probe_data=_pd(spec), repeats=2,
                        max_configs_per_size=N_CFG, seed=SEED,
                        register=False)
        v0 = _artifacts(tmp_path / "cache")
        ledger = tmp_path / "flight.jsonl"
        ledger.write_text(json.dumps(_drift_line()) + "\n")
        q = RetuneQueue(tmp_path / "state.json")
        assert q.ingest(ledger) == 1
        gen_before = registry.generation
        with FleetCoordinator(
                tmp_path / "spool", _device(), hw=V5E, cache=cache,
                config=FleetConfig(n_workers=2, backend="process",
                                   job_timeout_s=120)) as fc:
            outcomes = fc.retune(
                q, refs, budget=SearchBudget(max_executions=600), seed=SEED)
        assert registry.generation == gen_before    # serving untouched
        assert len(outcomes) == 1 and outcomes[0]["succeeded"]
        assert outcomes[0]["cache_version"] >= 1
        assert q.summary() == {**q.summary(), "done": 1, "pending": 0}
        # the durable outcome: a new artifact generation in the cache
        assert _artifacts(tmp_path / "cache") != v0

    def test_unknown_kernel_marked_failed(self, tmp_path):
        ledger = tmp_path / "flight.jsonl"
        ledger.write_text(
            json.dumps(_drift_line(kernel="no_such_kernel")) + "\n")
        q = RetuneQueue(tmp_path / "state.json")
        q.ingest(ledger)
        with FleetCoordinator(tmp_path / "spool", _device(),
                              cache=DriverCache(str(tmp_path / "cache")),
                              config=FleetConfig(n_workers=0)) as fc:
            assert fc.retune(q, tier1_spec_refs()) == []
        assert q.summary()["failed"] == 1


class TestCacheAtomicity:
    def test_concurrent_same_key_puts_never_tear(self, tmp_path):
        """Hammer one entry from many threads while readers poll: every
        read sees a complete JSON document, and no temp files leak."""
        cache = DriverCache(str(tmp_path / "cache"))
        spec = tier1_spec_refs()["matmul_b16"].build()
        kl = Klaraptor(_device(), hw=V5E, cache=cache)
        built = kl.build_driver(spec, probe_data=_pd(spec), repeats=2,
                                max_configs_per_size=N_CFG, seed=SEED,
                                register=False)
        paths = glob.glob(os.path.join(str(tmp_path / "cache"), "**",
                                       "*.json"), recursive=True)
        assert len(paths) == 1
        doc = json.load(open(paths[0]))
        stop = threading.Event()
        torn = []

        def reader():
            while not stop.is_set():
                try:
                    json.load(open(paths[0]))
                except ValueError as e:
                    torn.append(repr(e))

        def writer():
            for _ in range(50):
                from repro.core.cache import _write_json_atomic
                _write_json_atomic(paths[0], doc)

        threads = [threading.Thread(target=reader) for _ in range(2)] + \
                  [threading.Thread(target=writer) for _ in range(4)]
        for t in threads:
            t.start()
        time.sleep(0.3)
        stop.set()
        for t in threads:
            t.join(timeout=5)
        assert torn == []
        leftovers = [p for p in os.listdir(os.path.dirname(paths[0]))
                     if p.endswith(".tmp")]
        assert leftovers == []
        assert built is not None


class TestFleetCLI:
    def test_status_with_nothing_to_show(self, capsys):
        from repro.launch.fleet import main
        assert main(["status"]) == 1

    def test_tune_cli_smoke(self, tmp_path, capsys):
        from repro.launch.fleet import main
        rc = main(["tune", "--spool", str(tmp_path / "spool"),
                   "--workers", "2", "--kernels", "matmul_b16",
                   "--max-configs-per-size", "4", "--repeats", "2",
                   "--cache", str(tmp_path / "cache")])
        out = capsys.readouterr().out
        assert rc == 0
        assert "matmul_b16" in out and "farmed" in out

    def test_worker_id_with_dot_rejected(self, tmp_path):
        from repro.launch.fleet import main
        with pytest.raises(SystemExit):
            main(["worker", "--spool", str(tmp_path / "spool"),
                  "--id", "bad.id"])

    def test_retune_cli_empty_queue(self, tmp_path, capsys):
        from repro.launch.fleet import main
        rc = main(["retune", "--spool", str(tmp_path / "spool"),
                   "--state", str(tmp_path / "state.json")])
        assert rc == 0
        assert "nothing pending" in capsys.readouterr().out
