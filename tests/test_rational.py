"""Unit tests: rational programs, occupancy flowcharts, polynomials."""

import math

import numpy as np
import pytest

from repro.core import (Polynomial, RationalFunction, cuda_occupancy_program,
                        tpu_pipeline_occupancy_program)
from repro.core.rational_program import (Ceil, Const, Floor, Max, Min, Select,
                                         ceil_div, const, floor_div, var)


class TestPolynomial:
    def test_eval(self):
        # 2 + 3*x*y + x^2 over vars (x, y)
        p = Polynomial(("x", "y"), ((0, 0), (1, 1), (2, 0)),
                       np.array([2.0, 3.0, 1.0]))
        X = np.array([[1.0, 2.0], [3.0, 0.5]])
        np.testing.assert_allclose(p(X), [2 + 6 + 1, 2 + 4.5 + 9])

    def test_source_roundtrip(self):
        p = Polynomial(("x", "y"), ((0, 0), (1, 2)), np.array([1.5, -2.0]))
        src = p.to_source()
        for x, y in [(1.0, 2.0), (3.0, -1.0)]:
            assert eval(src) == pytest.approx(p(np.array([[x, y]]))[0])


class TestRationalFunction:
    def test_eval_and_json(self):
        rf = RationalFunction.from_coeffs(
            ("x",), [(0,), (1,)], np.array([1.0, 2.0]),
            [(0,), (1,)], np.array([1.0, 0.5]))
        X = np.array([[2.0]])
        assert rf(X)[0] == pytest.approx((1 + 4) / (1 + 1))
        rf2 = RationalFunction.from_json(rf.to_json())
        assert rf2(X)[0] == pytest.approx(rf(X)[0])

    def test_denominator_stability(self):
        rf = RationalFunction.from_coeffs(
            ("x",), [(0,)], np.array([1.0]),
            [(0,), (1,)], np.array([-1.0, 1.0]))   # pole at x=1
        X = np.linspace(0.5, 2.0, 10)[:, None]
        assert not rf.denominator_sign_stable(X)
        X2 = np.linspace(2.0, 5.0, 10)[:, None]
        assert rf.denominator_sign_stable(X2)


class TestExprIR:
    def test_arith_and_pieces(self):
        x, y = var("x"), var("y")
        e = Select(x > y, x * const(2.0), y - x)
        assert e.count_pieces() == 2
        assert e.eval({"x": 3.0, "y": 1.0}) == 6.0
        assert e.eval({"x": 1.0, "y": 5.0}) == 4.0

    def test_floor_ceil_div(self):
        assert floor_div(var("a"), var("b")).eval({"a": 7, "b": 2}) == 3
        assert ceil_div(var("a"), var("b")).eval({"a": 7, "b": 2}) == 4

    def test_vectorized_eval(self):
        e = Min(var("a"), const(4.0)) + Max(var("b"), const(0.0))
        out = e.eval({"a": np.array([1.0, 9.0]), "b": np.array([-1.0, 2.0])})
        np.testing.assert_allclose(out, [1.0, 6.0])

    def test_source_matches_eval(self):
        e = Select(var("x") >= const(2.0),
                   Floor(var("x") / const(2.0)) * const(3.0),
                   Ceil(var("x") * const(0.5)))
        src = e.to_source()
        for xv in (0.5, 1.9, 2.0, 7.3):
            got = eval(src, {"math": math, "x": xv})
            assert got == pytest.approx(float(e.eval({"x": xv})))


class TestOccupancyPrograms:
    def test_cuda_occupancy_five_pieces(self):
        # Fig. 2 has exactly 5 terminating leaves.
        occ = cuda_occupancy_program()
        assert occ.outputs["B_active"].count_pieces() == 5

    def test_cuda_occupancy_vs_bruteforce(self):
        occ = cuda_occupancy_program()
        H = dict(R_max=65536, Z_max=49152, T_max=1024, B_max=32, W_max=64)

        def brute(R, Z, T):
            if T > H["T_max"] or R * T > H["R_max"]:
                return 0
            if Z > 0 and Z > H["Z_max"]:
                return 0
            b = min(H["B_max"], H["T_max"] // T, H["R_max"] // (R * T))
            if Z > 0:
                b = min(b, H["Z_max"] // Z)
            return min((b * T) // 32, H["W_max"])

        rng = np.random.RandomState(0)
        for _ in range(200):
            R = int(rng.choice([16, 32, 64, 128, 255]))
            Z = int(rng.choice([0, 1024, 4096, 65536]))
            T = int(rng.choice([32, 128, 256, 512, 1024, 2048]))
            got = occ.eval({**H, "R": R, "Z": Z, "T": T}, output="W_active")
            assert got == brute(R, Z, T), (R, Z, T)

    def test_tpu_occupancy(self):
        occ = tpu_pipeline_occupancy_program()
        env = {"vmem": 128 * 2 ** 20, "stage_bytes": 30 * 2 ** 20}
        assert occ.eval(env, output="buffers") == 3
        assert occ.eval(env, output="overlap") == 1.0
        env["stage_bytes"] = 100 * 2 ** 20
        assert occ.eval(env, output="buffers") == 1
        assert occ.eval(env, output="overlap") == 0.0

    def test_flowchart_export(self):
        occ = cuda_occupancy_program()
        chart = occ.to_flowchart()
        assert "decide" in chart and "compute" in chart
