"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs ref.py oracle."""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.matmul import matmul_pallas
from repro.kernels.moe_gmm import moe_gmm_pallas
from repro.kernels.ssd_scan import ssd_scan_pallas

RNG = np.random.RandomState(0)


def _tol(dtype):
    return 2e-2 if dtype == jnp.bfloat16 else 2e-4


class TestMatmul:
    @pytest.mark.parametrize("m,n,k", [(64, 128, 128), (128, 256, 384),
                                       (256, 128, 512), (8, 128, 128)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_ref(self, m, n, k, dtype):
        x = (RNG.randn(m, k) * 0.5).astype(dtype)
        y = (RNG.randn(k, n) * 0.5).astype(dtype)
        out = matmul_pallas(x, y, bm=min(64, m), bn=128, bk=128,
                            interpret=True)
        exp = ref.matmul_ref(x, y)
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(exp, np.float32),
                                   atol=_tol(dtype), rtol=_tol(dtype))

    @pytest.mark.parametrize("bm,bn,bk", [(8, 128, 128), (32, 256, 128),
                                          (64, 128, 256), (128, 128, 128)])
    def test_block_sweep(self, bm, bn, bk):
        m, n, k = 128, 256, 256
        x = RNG.randn(m, k).astype(np.float32)
        y = RNG.randn(k, n).astype(np.float32)
        out = matmul_pallas(x, y, bm=bm, bn=bn, bk=bk, interpret=True)
        np.testing.assert_allclose(out, x @ y, atol=1e-3, rtol=1e-4)


class TestFlashAttention:
    @pytest.mark.parametrize("variant", ["causal", "full", "window",
                                         "softcap", "gqa4"])
    def test_variants(self, variant):
        b, hq, hkv, s, d = 2, 4, 2, 256, 64
        if variant == "gqa4":
            hkv = 1
        kw = dict(causal=True)
        if variant == "full":
            kw = dict(causal=False)
        elif variant == "window":
            kw = dict(causal=True, window=64)
        elif variant == "softcap":
            kw = dict(causal=True, softcap=30.0)
        q = (RNG.randn(b * hq, s, d) * 0.3).astype(np.float32)
        k = (RNG.randn(b * hkv, s, d) * 0.3).astype(np.float32)
        v = (RNG.randn(b * hkv, s, d) * 0.3).astype(np.float32)
        out = flash_attention_pallas(q, k, v, num_q_heads=hq,
                                     num_kv_heads=hkv, bq=64, bkv=128,
                                     interpret=True, **kw)
        exp = ref.flash_attention_ref(q, k, v, num_q_heads=hq,
                                      num_kv_heads=hkv, **kw)
        np.testing.assert_allclose(out, exp, atol=2e-3, rtol=1e-3)

    @pytest.mark.parametrize("bq,bkv", [(64, 128), (128, 128), (256, 256)])
    def test_block_sweep(self, bq, bkv):
        b, hq, hkv, s, d = 1, 2, 2, 256, 64
        q = (RNG.randn(b * hq, s, d) * 0.3).astype(np.float32)
        k = (RNG.randn(b * hkv, s, d) * 0.3).astype(np.float32)
        v = (RNG.randn(b * hkv, s, d) * 0.3).astype(np.float32)
        out = flash_attention_pallas(q, k, v, num_q_heads=hq,
                                     num_kv_heads=hkv, bq=bq, bkv=bkv,
                                     interpret=True)
        exp = ref.flash_attention_ref(q, k, v, num_q_heads=hq,
                                      num_kv_heads=hkv)
        np.testing.assert_allclose(out, exp, atol=2e-3, rtol=1e-3)

    def test_chunked_ref_equals_naive(self):
        b, hq, hkv, s, d = 2, 4, 2, 256, 32
        q = (RNG.randn(b * hq, s, d) * 0.3).astype(np.float32)
        k = (RNG.randn(b * hkv, s, d) * 0.3).astype(np.float32)
        v = (RNG.randn(b * hkv, s, d) * 0.3).astype(np.float32)
        for kw in [dict(causal=True), dict(causal=False),
                   dict(causal=True, window=32),
                   dict(causal=True, softcap=20.0)]:
            a = ref.flash_attention_ref(q, k, v, num_q_heads=hq,
                                        num_kv_heads=hkv, **kw)
            c = ref.flash_attention_ref(q, k, v, num_q_heads=hq,
                                        num_kv_heads=hkv, q_chunk=64, **kw)
            np.testing.assert_allclose(a, c, atol=1e-5)


class TestMoeGmm:
    @pytest.mark.parametrize("e,g,k,n", [(2, 64, 128, 128), (4, 128, 256, 128),
                                         (8, 32, 128, 384)])
    def test_matches_ref(self, e, g, k, n):
        x = (RNG.randn(e, g, k) * 0.3).astype(np.float32)
        w = (RNG.randn(e, k, n) * 0.3).astype(np.float32)
        out = moe_gmm_pallas(x, w, bg=32, bn=128, bk=128, interpret=True)
        exp = ref.moe_gmm_ref(x, w)
        np.testing.assert_allclose(out, exp, atol=1e-3, rtol=1e-4)


class TestSSDScan:
    @pytest.mark.parametrize("chunk", [128, 256])
    @pytest.mark.parametrize("s", [256, 512])
    def test_matches_recurrence(self, chunk, s):
        bh, dh, n = 3, 64, 32
        x = (RNG.randn(bh, s, dh) * 0.5).astype(np.float32)
        dt = (0.01 + 0.5 * RNG.rand(bh, s)).astype(np.float32)
        B = (RNG.randn(bh, s, n) * 0.3).astype(np.float32)
        C = (RNG.randn(bh, s, n) * 0.3).astype(np.float32)
        A = (-0.5 - RNG.rand(bh)).astype(np.float32)
        out = ssd_scan_pallas(x, dt, B, C, A, chunk=chunk, interpret=True)
        exp = ref.ssd_scan_ref(x, dt, B, C, A)
        np.testing.assert_allclose(out, exp, atol=5e-3, rtol=1e-3)

    def test_parallel_form_matches_recurrence(self):
        from repro.models.layers import ssd_parallel
        bh, s, dh, n = 2, 512, 32, 16
        x = (RNG.randn(bh, s, dh) * 0.5).astype(np.float32)
        dt = (0.01 + 0.5 * RNG.rand(bh, s)).astype(np.float32)
        B = (RNG.randn(bh, s, n) * 0.3).astype(np.float32)
        C = (RNG.randn(bh, s, n) * 0.3).astype(np.float32)
        A = (-0.5 - RNG.rand(bh)).astype(np.float32)
        out = ssd_parallel(x, dt, B, C, A, chunk=128)
        exp = ref.ssd_scan_ref(x, dt, B, C, A)
        np.testing.assert_allclose(out, exp, atol=5e-3, rtol=1e-3)
