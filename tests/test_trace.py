"""repro.trace tests: span nesting/attrs (including under threads), the
zero-cost disabled path, Chrome trace-event export validity, the flight
ledger round-trip against the live exporter, Prometheus histogram/label
hardening, the new registry gauges, the status CLI, and the refit causal
span tree."""

import json
import threading

import pytest

from repro.core import (Klaraptor, V5E, V5eSimulator, matmul_spec, registry)
from repro.core.driver import (ChoiceEvent, choose_or_default,
                               set_choice_listener)
from repro.search import SearchBudget
from repro.telemetry import (RefitController, Telemetry, TelemetryConfig,
                             shape_bucket)
from repro.telemetry.drift import DriftEvent
from repro.trace import (HISTOGRAM_BOUNDS_S, Ledger, NULL_SPAN, Tracer,
                         get_tracer, ledger_summary, read_ledger, set_tracer,
                         trace_span, traced, tracing)

D_SMALL = {"m": 1024, "n": 1024, "k": 1024}
MM_DEFAULT = {"bm": 128, "bn": 512, "bk": 512}


@pytest.fixture(autouse=True)
def no_ambient_tracer():
    """Every test starts and ends with tracing disabled: the exporter
    determinism tests elsewhere rely on the process-wide slot being
    empty."""
    set_tracer(None)
    yield
    set_tracer(None)


@pytest.fixture()
def clean(tmp_path, monkeypatch):
    monkeypatch.setenv("KLARAPTOR_CACHE_DIR", str(tmp_path / "cache"))
    registry.clear()
    set_choice_listener(None)
    yield str(tmp_path / "cache")
    set_choice_listener(None)
    registry.clear()


class TestSpans:
    def test_disabled_is_shared_noop(self):
        assert get_tracer() is None and not tracing()
        # Same object every call: the off path allocates nothing per span.
        s = trace_span("anything", k=1)
        assert s is NULL_SPAN and trace_span("other") is s
        with s as inner:
            assert inner.set(a=1) is inner     # attrs silently dropped

    def test_traced_decorator_disabled_is_passthrough(self):
        @traced("f")
        def f(x):
            return x + 1
        assert f(1) == 2
        with Tracer() as tr:
            assert f(2) == 3
        assert [s.name for s in tr.spans()] == ["f"]

    def test_nesting_depth_attrs_and_order(self):
        with Tracer() as tr:
            with trace_span("outer", kernel="mm") as o:
                with trace_span("inner"):
                    pass
                o.set(result=7)
        inner, outer = tr.spans()
        assert (outer.name, outer.depth) == ("outer", 0)
        assert (inner.name, inner.depth) == ("inner", 1)
        assert outer.attrs == {"kernel": "mm", "result": 7}
        # child completes first and fits inside the parent's window
        assert outer.t0_ns <= inner.t0_ns <= inner.t1_ns <= outer.t1_ns

    def test_exception_closes_span_and_marks_error(self):
        with Tracer() as tr:
            with pytest.raises(ValueError):
                with trace_span("boom"):
                    raise ValueError("x")
            with trace_span("after"):
                pass
        boom, after = tr.spans()
        assert boom.attrs["error"] == "ValueError"
        assert after.depth == 0       # stack fully unwound by the raise

    def test_ring_is_bounded_but_counts_everything(self):
        with Tracer(capacity=4) as tr:
            for i in range(10):
                with trace_span(f"s{i}"):
                    pass
        assert tr.n_spans == 10
        assert [s.name for s in tr.spans()] == ["s6", "s7", "s8", "s9"]
        # histograms aggregate past the ring
        assert sum(h["count"] for h in tr.histograms().values()) == 10

    def test_threads_get_independent_stacks(self):
        barrier = threading.Barrier(4)
        with Tracer() as tr:
            def work(tag):
                barrier.wait()      # all four nest concurrently
                with trace_span("outer", tag=tag):
                    with trace_span("inner", tag=tag):
                        pass
            threads = [threading.Thread(target=work, args=(i,), name=f"w{i}")
                       for i in range(4)]
            for t in threads:
                t.start()
            for t in threads:
                t.join()
        spans = tr.spans()
        assert len(spans) == 8
        by_thread = {}
        for s in spans:
            by_thread.setdefault(s.thread_name, []).append(s)
        assert set(by_thread) == {f"w{i}" for i in range(4)}
        for group in by_thread.values():
            # each thread saw its own 0/1 nesting, never a neighbour's
            assert sorted(s.depth for s in group) == [0, 1]
            assert all(s.tid for s in group)

    def test_summary_ranked_by_cumulative_time(self):
        with Tracer() as tr:
            for _ in range(3):
                with trace_span("cheap"):
                    pass
            with trace_span("dear"):
                t0 = tr  # noqa: F841 -- just burn a little time
                sum(range(20000))
        rows = tr.summary()
        assert [r["name"] for r in rows] == ["dear", "cheap"]
        assert rows[1]["count"] == 3
        assert rows[0]["max_s"] >= rows[0]["mean_s"] > 0


class TestChromeExport:
    def test_chrome_trace_schema_and_containment(self, tmp_path):
        with Tracer() as tr:
            with trace_span("parent", kernel="mm", cfg={"bm": 128}):
                with trace_span("child", obj=object()):
                    pass
        payload = tr.chrome_trace()
        # round-trips through strict JSON (the object() attr stringified)
        payload = json.loads(json.dumps(payload))
        events = payload["traceEvents"]
        meta = [e for e in events if e["ph"] == "M"]
        assert {m["name"] for m in meta} >= {"process_name", "thread_name"}
        xs = {e["name"]: e for e in events if e["ph"] == "X"}
        assert set(xs) == {"parent", "child"}
        for e in xs.values():
            assert e["ts"] >= 0 and e["dur"] >= 0
            assert isinstance(e["pid"], int) and isinstance(e["tid"], int)
        p, c = xs["parent"], xs["child"]
        assert p["ts"] <= c["ts"]
        assert c["ts"] + c["dur"] <= p["ts"] + p["dur"] + 1e-3
        assert p["args"]["cfg"] == {"bm": 128}
        out = tmp_path / "trace.json"
        assert tr.write_chrome_trace(out) == 2
        assert json.loads(out.read_text())["traceEvents"]


class TestHistogramsAndPrometheus:
    def test_bucket_counts(self):
        from repro.trace import SpanHistogram
        h = SpanHistogram()
        h.add(500)             # 0.5us -> first bucket (<= 1us)
        h.add(5_000_000)       # 5ms   -> <= 1e-2 bucket
        h.add(int(20e9))       # 20s   -> +Inf overflow
        assert h.counts[0] == 1 and h.counts[-1] == 1
        assert h.count == 3 and h.max_ns == int(20e9)

    def test_prometheus_span_histogram_lines(self, clean):
        tel = Telemetry({}, V5eSimulator())
        with Tracer():
            for _ in range(4):
                with trace_span("fit"):
                    pass
            text = tel.prometheus()
        assert "# TYPE klaraptor_span_duration_seconds histogram" in text
        buckets = [int(line.rsplit(" ", 1)[1])
                   for line in text.splitlines()
                   if line.startswith("klaraptor_span_duration_seconds_bucket"
                                      '{span="fit"')]
        assert len(buckets) == len(HISTOGRAM_BOUNDS_S) + 1   # incl. +Inf
        assert buckets == sorted(buckets)                    # cumulative
        assert buckets[-1] == 4
        assert 'le="+Inf"' in text
        assert 'klaraptor_span_duration_seconds_count{span="fit"} 4' in text

    def test_prometheus_without_tracer_has_no_span_section(self, clean):
        tel = Telemetry({}, V5eSimulator())
        assert "span_duration_seconds" not in tel.prometheus()
        assert "spans" not in tel.snapshot()

    def test_label_escaping_regression(self, clean):
        # A kernel name containing a quote and a backslash used to emit an
        # unparseable exposition line.
        evil = 'mm"42\\x'
        tel = Telemetry({}, V5eSimulator()).install()
        try:
            choose_or_default(evil, {"m": 8}, MM_DEFAULT)
        finally:
            tel.uninstall()
        text = tel.prometheus()
        line = next(l for l in text.splitlines()
                    if l.startswith("klaraptor_key_choices_total"))
        assert '\\"' in line and "\\\\" in line
        assert evil not in line          # raw quote/backslash never leaks
        # and the snapshot keeps the unescaped truth
        assert tel.snapshot()["keys"][0]["kernel"] == evil


class TestChoiceEventTimestamp:
    def test_t_ns_stamped_when_listener_installed(self, clean):
        seen = []
        set_choice_listener(seen.append)
        choose_or_default("matmul_b16", D_SMALL, MM_DEFAULT)
        assert seen and seen[0].t_ns is not None and seen[0].t_ns > 0
        # and a hand-built event defaults to None (stamping is the
        # listener path's job, not the dataclass's)
        assert ChoiceEvent(kernel="k", D={}, config={}, source="default",
                           predicted_s=None, hw_name=V5E.name).t_ns is None


class TestRegistryGauges:
    def test_generation_memo_and_invalidation_gauges(self, clean):
        tel = Telemetry({}, V5eSimulator()).install()
        try:
            registry.note_override("matmul_b16", V5E.name, D_SMALL,
                                   MM_DEFAULT)
            snap0 = tel.snapshot()
            assert snap0["gauges"]["decision_memo_entries"] == 0
            # an override decision is memoized -> the gauge moves
            choose_or_default("matmul_b16", D_SMALL, MM_DEFAULT)
            snap1 = tel.snapshot()
            assert snap1["gauges"]["decision_memo_entries"] == 1
            # a registry mutation drops the memo and counts the kill
            registry.invalidate_kernel("matmul_b16")
            snap2 = tel.snapshot()
            assert snap2["gauges"]["registry_generation"] > \
                snap1["gauges"]["registry_generation"]
            assert snap2["gauges"]["decision_memo_entries"] == 0
            assert snap2["counters"]["memo_invalidations"] == \
                snap1["counters"]["memo_invalidations"] + 1
        finally:
            tel.uninstall()
        text = tel.prometheus()
        assert "# TYPE klaraptor_registry_generation gauge" in text
        assert "# TYPE klaraptor_decision_memo_entries gauge" in text
        assert "# TYPE klaraptor_memo_invalidations counter" in text
        assert "# TYPE klaraptor_plan_invalidations counter" in text


def _run_telemetry_with_ledger(tmp_path, refit=False):
    """Drive the real loop (simulator oracle) with a ledger attached."""
    path = tmp_path / "run.jsonl"
    cfg = TelemetryConfig(probe_every=1, min_samples=2, drift_threshold=0.2,
                          ewma_alpha=1.0, refit_enabled=refit,
                          refit_budget=SearchBudget(max_executions=24),
                          refit_repeats=1, refit_max_configs_per_size=4)
    tel = Telemetry([matmul_spec()], V5eSimulator(seed=3), config=cfg,
                    cache=False, ledger=str(path))
    tel.install()
    try:
        for _ in range(4):
            # fabricated optimistic prediction -> rel error > threshold
            tel._on_choice(ChoiceEvent(
                kernel="matmul_b16", D=dict(D_SMALL),
                config=dict(MM_DEFAULT), source="driver",
                predicted_s=1e-9, hw_name=V5E.name))
    finally:
        tel.uninstall()
        tel.ledger.close()
    return tel, path


class TestLedger:
    def test_round_trip_matches_exporter(self, clean, tmp_path):
        tel, path = _run_telemetry_with_ledger(tmp_path)
        events = read_ledger(path)
        s = ledger_summary(events)
        snap = tel.snapshot()
        assert s["choices_total"] == snap["counters"]["choices_total"]
        assert s["by_type"]["probe"] == \
            snap["counters"]["shadow_probes_total"]
        assert len(s["drift_events"]) == \
            snap["counters"]["drift_events_total"] > 0
        assert s["kernels"]["matmul_b16"]["by_source"]["driver"] == 4
        key = f"matmul_b16 {V5E.name} {list(s['rel_error'])[0].split(' ', 2)[2]}"
        assert s["rel_error"][key]["probes"] == \
            snap["counters"]["shadow_probes_total"]
        assert s["rel_error"][key]["rel_error_ewma"] == pytest.approx(
            snap["keys"][0]["rel_error_ewma"])

    def test_refit_lines_and_torn_tail(self, clean, tmp_path):
        tel, path = _run_telemetry_with_ledger(tmp_path, refit=True)
        with open(path, "a") as f:
            f.write('{"type": "choice", "torn')   # killed mid-write
        events = read_ledger(path)
        s = ledger_summary(events)
        assert len(s["refits"]) == tel.counters.refits_total > 0
        # coalesced weighting: a synthetic n_coalesced choice counts fully
        extra = dict(next(e for e in events if e["type"] == "choice"))
        extra["n_coalesced"] = 64
        s2 = ledger_summary(events + [extra])
        assert s2["choices_total"] == s["choices_total"] + 64

    def test_mid_file_corruption_strict_vs_lenient(self, tmp_path):
        path = tmp_path / "bad.jsonl"
        path.write_text('{"type": "choice"}\nnot json\n{"type": "probe"}\n')
        # Default is lenient: corrupt mid-file lines are skipped (counted
        # in a warning), the good lines survive -- what the fleet's
        # drift-queue ingest relies on.
        events = read_ledger(path)
        assert [e["type"] for e in events] == ["choice", "probe"]
        with pytest.raises(json.JSONDecodeError):
            read_ledger(path, strict=True)
        # A torn *tail* is tolerated even in strict mode.
        path.write_text('{"type": "choice"}\n{"type": "torn')
        assert [e["type"] for e in read_ledger(path, strict=True)] == \
            ["choice"]

    def test_tracer_spans_reach_ledger(self, tmp_path):
        path = tmp_path / "spans.jsonl"
        with Ledger(path) as led:
            with Tracer(ledger=led):
                with trace_span("a", kernel="mm"):
                    with trace_span("b"):
                        pass
        events = read_ledger(path)
        # every open writes one wall<->monotonic session anchor first
        assert events[0]["type"] == "session"
        assert {"wall_ns", "mono_ns", "pid"} <= set(events[0])
        spans = [e for e in events if e["type"] == "span"]
        assert [(e["name"], e["depth"]) for e in spans] == \
            [("b", 1), ("a", 0)]
        assert spans[1]["attrs"] == {"kernel": "mm"}
        assert ledger_summary(events)["spans"]["a"]["count"] == 1


class TestRefitSpanTree:
    def test_refit_chain_is_one_causal_tree(self, clean):
        kl = Klaraptor(V5eSimulator(noise=0.02, seed=5), cache=False)
        ctl = RefitController(
            kl, TelemetryConfig(refit_budget=SearchBudget(max_executions=32),
                                refit_repeats=1,
                                refit_max_configs_per_size=4))
        drift = DriftEvent(kernel="matmul_b16", hw_name=V5E.name,
                           bucket=shape_bucket(D_SMALL), D=dict(D_SMALL),
                           config=dict(MM_DEFAULT), rel_error_ewma=0.8,
                           n_samples=4, predicted_s=1e-9, observed_s=1e-3)
        with Tracer() as tr:
            ctl.refit(matmul_spec(), drift)
        by_name = {s.name: s for s in tr.spans()}
        assert {"refit", "refit.search", "refit.fit", "refit.validate",
                "refit.swap"} <= set(by_name)
        parent = by_name["refit"]
        for child in ("refit.search", "refit.fit", "refit.validate",
                      "refit.swap"):
            s = by_name[child]
            assert s.depth == parent.depth + 1
            assert parent.t0_ns <= s.t0_ns <= s.t1_ns <= parent.t1_ns
        assert "succeeded" in parent.attrs
        assert "executions" in by_name["refit.search"].attrs


class TestStatusCLI:
    def test_renders_ledger(self, clean, tmp_path, capsys):
        from repro.launch.status import main
        _, path = _run_telemetry_with_ledger(tmp_path, refit=True)
        assert main(["--ledger", str(path)]) == 0
        out = capsys.readouterr().out
        assert "decisions by kernel and source" in out
        assert "matmul_b16" in out
        assert "drift and refits" in out

    def test_renders_snapshot(self, clean, tmp_path, capsys):
        from repro.launch.status import main
        tel = Telemetry({}, V5eSimulator()).install()
        try:
            choose_or_default("matmul_b16", D_SMALL, MM_DEFAULT)
        finally:
            tel.uninstall()
        path = tmp_path / "snap.json"
        path.write_text(tel.exporter.json())
        assert main(["--snapshot", str(path), "--top", "3"]) == 0
        out = capsys.readouterr().out
        assert "decisions by source" in out and "default" in out

    def test_requires_exactly_one_source(self):
        from repro.launch.status import main
        with pytest.raises(SystemExit):
            main([])
