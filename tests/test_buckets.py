"""Bucketed in-graph dispatch: the static shape lattice, the switch-index
decision, and the pow2 prefill chunking that rides on it.

Load-bearing properties:
  * Host (``bucket_of``) and graph (``bucket_keys``) rounding are
    bit-identical on every value, including the lattice edges and the
    out-of-range boundary -- this is what lets host replay stand in for
    the compiled graph in the bench gates and engine bucket stats.
  * ``BucketedDispatch.branch_index`` inside ``jax.jit`` agrees with
    ``host_index`` on hits, unplanned buckets, and out-of-range misses,
    and a miss lands on the trailing default branch (never a retrace).
  * The in-graph op path (``ops.matmul(..., in_graph=...)``) serves many
    raw shapes from ONE trace with outputs allclose to the unpadded
    reference.
  * ``ServingEngine._pow2_chunks`` covers any prompt length exactly with
    a log-bounded set of chunk sizes.
"""

import numpy as np
import pytest

from repro.core import (BucketLattice, V5E, matmul_spec, pad_to, pow2_span,
                        set_choice_listener)
from repro.core.device_plan import BucketedDispatch
from repro.core.plan import LaunchPlanTable


# ---------------------------------------------------------------------------
# Lattice primitives
# ---------------------------------------------------------------------------

class TestPrimitives:
    def test_pow2_span(self):
        assert pow2_span(64, 1024) == (64, 128, 256, 512, 1024)
        assert pow2_span(65, 1024) == (128, 256, 512, 1024)
        assert pow2_span(512, 512) == (512,)
        assert pow2_span(1, 1) == (1,)
        assert pow2_span(0, 4) == (1, 2, 4)

    def test_pad_to(self):
        import jax.numpy as jnp
        x = jnp.arange(6, dtype=jnp.float32).reshape(2, 3)
        out = pad_to(x, (4, 3))
        assert out.shape == (4, 3)
        np.testing.assert_array_equal(np.asarray(out)[2:], 0.0)
        np.testing.assert_array_equal(np.asarray(out)[:2], np.asarray(x))
        # None keeps a dimension; an exact match is the identity object
        assert pad_to(x, (None, 5)).shape == (2, 5)
        assert pad_to(x, (2, 3)) is x
        with pytest.raises(ValueError, match="smaller than extent"):
            pad_to(x, (1, 3))

    def test_from_axes_validates(self):
        lat = BucketLattice.from_axes("k", {"m": [256, 64, 64, 128]})
        assert lat.axes == (("m", (64, 128, 256)),)
        with pytest.raises(ValueError, match="positive"):
            BucketLattice.from_axes("k", {"m": [0, 64]})
        with pytest.raises(ValueError, match="positive"):
            BucketLattice.from_axes("k", {"m": []})


# ---------------------------------------------------------------------------
# Host/graph rounding bit-identity
# ---------------------------------------------------------------------------

class TestRounding:
    LAT = BucketLattice.from_axes("k", {"m": [8, 64, 256], "n": [128, 512]})

    def test_bucket_of_edges(self):
        lat = self.LAT
        assert lat.bucket_of({"m": 1, "n": 1}) == {"m": 8, "n": 128}
        assert lat.bucket_of({"m": 8, "n": 128}) == {"m": 8, "n": 128}
        assert lat.bucket_of({"m": 9, "n": 128}) == {"m": 64, "n": 128}
        assert lat.bucket_of({"m": 256, "n": 512}) == {"m": 256, "n": 512}
        # out of range: above the top, non-positive, missing param
        assert lat.bucket_of({"m": 257, "n": 128}) is None
        assert lat.bucket_of({"m": 0, "n": 128}) is None
        assert lat.bucket_of({"m": 8}) is None
        # extra keys ignored
        assert lat.bucket_of({"m": 8, "n": 128, "zz": 1}) is not None

    def test_host_graph_bit_identical_sweep(self):
        """Every (m, n) in a sweep spanning in-range, edges, and
        out-of-range: the jitted graph rounding must agree with the host
        exactly -- keys on hits, the in_range mask on misses."""
        import jax
        import jax.numpy as jnp

        lat = self.LAT

        @jax.jit
        def graph_round(raw):
            return lat.bucket_keys(raw)

        ms = [0, 1, 7, 8, 9, 63, 64, 65, 255, 256, 257, 1000]
        ns = [0, 1, 127, 128, 129, 511, 512, 513]
        for m in ms:
            for n in ns:
                keys, in_range = graph_round(
                    jnp.asarray([m, n], dtype=jnp.int32))
                host = lat.bucket_of({"m": m, "n": n})
                if host is None:
                    assert not bool(in_range), (m, n)
                else:
                    assert bool(in_range), (m, n)
                    assert tuple(int(v) for v in np.asarray(keys)) == \
                        (host["m"], host["n"]), (m, n)

    def test_padding_waste(self):
        lat = self.LAT
        assert lat.padding_waste({"m": 8, "n": 128}) == 0.0
        w = lat.padding_waste({"m": 32, "n": 128})
        assert w == pytest.approx(1.0 - 32 / 64)
        assert lat.padding_waste({"m": 999, "n": 128}) == 0.0  # miss

    def test_introspection(self):
        lat = self.LAT
        assert lat.data_params == ("m", "n")
        assert lat.n_buckets == 6
        assert lat.envelope() == {"m": [8, 64, 256], "n": [128, 512]}
        assert lat.envelope_shape() == {"m": 256, "n": 512}
        assert len(lat.all_buckets()) == 6
        assert {"m": 8, "n": 128} in lat.all_buckets()


# ---------------------------------------------------------------------------
# Feasibility-derived construction
# ---------------------------------------------------------------------------

class _StubSpec:
    """Spec stand-in with a controllable feasibility frontier."""
    name = "stub"
    data_params = ("m", "k")

    def candidates(self, D, hw):
        return [object()] if D["m"] <= 256 and D["k"] <= 512 else []


class TestFromSpec:
    def test_trims_infeasible_top(self):
        lat = BucketLattice.from_spec(_StubSpec(), {"m": (16, 1024),
                                                    "k": (64, 512)})
        assert dict(lat.axes)["m"] == (16, 32, 64, 128, 256)
        assert dict(lat.axes)["k"] == (64, 128, 256, 512)

    def test_fixed_params_skip_feasibility(self):
        lat = BucketLattice.from_spec(_StubSpec(), {"m": (16, 256)},
                                      fixed={"k": [7, 9999]})
        assert dict(lat.axes)["k"] == (7, 9999)

    def test_no_feasible_values_raises(self):
        with pytest.raises(ValueError, match="no feasible"):
            BucketLattice.from_spec(_StubSpec(), {"m": (512, 1024),
                                                  "k": (64, 64)})

    def test_real_spec_orders_by_data_params(self):
        spec = matmul_spec()
        lat = BucketLattice.from_spec(
            spec, {"k": (512, 512), "m": (64, 256), "n": (256, 256)})
        assert lat.data_params == tuple(
            d for d in spec.data_params if d in ("m", "n", "k"))


# ---------------------------------------------------------------------------
# BucketedDispatch: the switch-index decision
# ---------------------------------------------------------------------------

def _hand_dispatch():
    """Lattice + hand-built plan table; bucket (256, 512) left unplanned
    so the in-range-but-unplanned miss path is reachable."""
    lat = BucketLattice.from_axes("k", {"m": [64, 128, 256],
                                        "n": [256, 512]})
    shapes = {"m": np.array([64, 64, 128, 128, 256]),
              "n": np.array([256, 512, 256, 512, 256])}
    configs = {"bm": np.array([8, 8, 16, 16, 32]),
               "bn": np.array([128, 256, 128, 256, 128])}
    table = LaunchPlanTable.build("k", V5E.name, ("m", "n"), ("bm", "bn"),
                                  shapes, configs)
    return BucketedDispatch.build(lat, table, {"bm": 8, "bn": 128})


class TestBucketedDispatch:
    def test_static_config_set(self):
        disp = _hand_dispatch()
        # 5 planned rows, all distinct -> 5 configs + trailing default
        assert disp.configs == ((8, 128), (8, 256), (16, 128), (16, 256),
                                (32, 128))
        assert disp.n_branches == 6
        assert disp.config_dicts()[-1] == {"bm": 8, "bn": 128}
        assert len(disp.config_dicts()) == disp.n_branches

    def test_graph_matches_host_on_all_paths(self):
        import jax
        import jax.numpy as jnp

        disp = _hand_dispatch()

        @jax.jit
        def decide(dims):
            return disp.branch_index(dims)

        cases = [
            {"m": 64, "n": 256},    # exact bucket hit
            {"m": 33, "n": 200},    # rounded-up hit
            {"m": 129, "n": 300},   # rounds to (256, 512): unplanned miss
            {"m": 256, "n": 512},   # unplanned bucket, exact
            {"m": 300, "n": 256},   # out of range (above top)
            {"m": 0, "n": 256},     # out of range (non-positive)
        ]
        for D in cases:
            idx, hit = decide(jnp.asarray([D["m"], D["n"]], jnp.int32))
            h_idx, h_hit = disp.host_index(D)
            assert (int(idx), bool(hit)) == (h_idx, h_hit), D
        # hits resolve to a real branch, misses to the trailing default
        assert disp.host_index({"m": 64, "n": 256})[1] is True
        for D in cases[2:]:
            assert disp.host_index(D) == (len(disp.configs), False), D

    def test_host_config_matches_table(self):
        disp = _hand_dispatch()
        cfg, hit = disp.host_config({"m": 100, "n": 300})  # -> (128, 512)
        assert hit and cfg == {"bm": 16, "bn": 256}
        cfg, hit = disp.host_config({"m": 200, "n": 600})  # out of range
        assert not hit and cfg == {"bm": 8, "bn": 128}

    def test_observe_emits_bucket_events(self):
        disp = _hand_dispatch()
        events = []
        set_choice_listener(events.append)
        try:
            hit, waste = disp.observe({"m": 33, "n": 200}, n_coalesced=3)
            assert hit and waste == pytest.approx(
                disp.lattice.padding_waste({"m": 33, "n": 200}))
            miss_hit, miss_waste = disp.observe({"m": 999, "n": 256})
            assert not miss_hit and miss_waste == 0.0
        finally:
            set_choice_listener(None)
        assert [e.source for e in events] == ["bucket", "default"]
        assert events[0].n_coalesced == 3
        assert events[0].config == {"bm": 8, "bn": 128}
        assert events[1].config == {"bm": 8, "bn": 128}  # default branch

    def test_mismatched_params_rejected(self):
        lat = BucketLattice.from_axes("k", {"m": [64]})
        table = LaunchPlanTable.build(
            "k", V5E.name, ("m", "n"), ("bm",),
            {"m": np.array([64]), "n": np.array([256])},
            {"bm": np.array([8])})
        with pytest.raises(ValueError, match="do not match"):
            BucketedDispatch.build(lat, table, {"bm": 8})

    def test_empty_table_always_defaults(self):
        lat = BucketLattice.from_axes("k", {"m": [64, 128]})
        table = LaunchPlanTable.build(
            "k", V5E.name, ("m",), ("bm",),
            {"m": np.zeros(0, dtype=np.int64)},
            {"bm": np.zeros(0, dtype=np.int64)})
        disp = BucketedDispatch.build(lat, table, {"bm": 32})
        assert disp.n_branches == 1
        assert disp.host_index({"m": 64}) == (0, False)
        assert disp.host_config({"m": 64}) == ({"bm": 32}, False)


# ---------------------------------------------------------------------------
# The in-graph op path: one trace, many shapes
# ---------------------------------------------------------------------------

class TestInGraphOps:
    def _matmul_dispatch(self):
        lat = BucketLattice.from_axes(
            "k", {"m": [64, 128], "n": [256], "k": [256]})
        shapes = {"m": np.array([64, 128]), "n": np.array([256, 256]),
                  "k": np.array([256, 256])}
        configs = {"bm": np.array([8, 16]), "bn": np.array([128, 256]),
                   "bk": np.array([128, 128])}
        table = LaunchPlanTable.build(
            "k", V5E.name, ("m", "n", "k"), ("bm", "bn", "bk"),
            shapes, configs)
        return BucketedDispatch.build(lat, table,
                                      {"bm": 8, "bn": 128, "bk": 128})

    def test_matmul_one_trace_many_shapes(self):
        import jax
        import jax.numpy as jnp

        from repro.kernels import ops

        disp = self._matmul_dispatch()
        traces = {"n": 0}

        @jax.jit
        def step(xp, yp, dims):
            traces["n"] += 1
            return ops.matmul(xp, yp, in_graph=disp, dims=dims,
                              interpret=True)

        rng = np.random.default_rng(0)
        for (m, n, k) in [(40, 200, 200), (64, 256, 256), (100, 130, 250),
                          (128, 256, 256), (7, 9, 11)]:
            x = rng.standard_normal((m, k)).astype(np.float32)
            y = rng.standard_normal((k, n)).astype(np.float32)
            xp = pad_to(jnp.asarray(x), (128, 256))
            yp = pad_to(jnp.asarray(y), (256, 256))
            out = np.asarray(step(xp, yp,
                                  jnp.asarray([m, n, k], jnp.int32)))
            np.testing.assert_allclose(out[:m, :n], x @ y,
                                       rtol=1e-4, atol=1e-4)
        assert traces["n"] == 1

    def test_flash_in_graph_requires_causal(self):
        import jax.numpy as jnp

        from repro.kernels import ops

        disp = self._matmul_dispatch()   # any dispatch; check is upfront
        q = jnp.zeros((2, 8, 64), jnp.float32)
        with pytest.raises(ValueError, match="causal"):
            ops.flash_attention(q, q, q, causal=False, num_q_heads=2,
                                num_kv_heads=2, in_graph=disp)


# ---------------------------------------------------------------------------
# pow2 prefill chunking
# ---------------------------------------------------------------------------

class TestPow2Chunks:
    def test_exact_cover_and_bounds(self):
        from repro.serving.engine import ServingEngine

        for cmax in (1, 2, 8, 32, 64):
            allowed = {c for c in (1, 2, 4, 8, 16, 32, 64) if c <= cmax}
            for n in list(range(0, 70)) + [127, 128, 129, 1000]:
                chunks = ServingEngine._pow2_chunks(n, cmax)
                assert sum(chunks) == n, (n, cmax)
                assert all(c in allowed for c in chunks), (n, cmax)
                # descending, so at most one of each size below the cap:
                # the trace-cache bound log2(cmax)+1 plus repeats of cmax
                assert chunks == sorted(chunks, reverse=True), (n, cmax)
                below_cap = [c for c in chunks if c < cmax]
                assert len(below_cap) == len(set(below_cap)), (n, cmax)

    def test_trace_set_is_log_bounded(self):
        from repro.serving.engine import ServingEngine

        sizes = set()
        for n in range(1, 2000):
            sizes.update(ServingEngine._pow2_chunks(n, 64))
        assert sizes == {1, 2, 4, 8, 16, 32, 64}   # log2(64)+1 traces
