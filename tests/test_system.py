"""End-to-end system tests: training convergence, fault-tolerant resume,
serving engine, and the full KLARAPTOR tune->train integration."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import ShapePreset
from repro.core import Klaraptor, V5eSimulator, matmul_spec
from repro.core.driver import registry
from repro.launch.train import TrainLoop
from repro.serving import Request
from repro.launch.serve import build_engine


def _loop(tmp_path=None, steps_cfg=None, arch="llama3.2-1b", **kw):
    cfg = get_config(arch, smoke=True)
    if steps_cfg:
        cfg = cfg.replace(**steps_cfg)
    preset = ShapePreset("t", "train", 64, 4)
    return TrainLoop(cfg, preset, mesh=None,
                     ckpt_dir=str(tmp_path) if tmp_path else None, **kw)


@pytest.mark.slow
class TestTraining:
    def test_loss_decreases(self):
        loop = _loop()
        loop.init_state()
        hist = loop.run(50, log_every=5)
        first, last = hist[0]["loss"], hist[-1]["loss"]
        assert last < first - 0.4, (first, last)
        assert np.isfinite(last)

    def test_checkpoint_resume_bit_exact(self, tmp_path):
        # run A: 30 steps straight through
        a = _loop(tmp_path / "a", ckpt_every=10)
        a.init_state()
        a.run(30, log_every=30)
        a.save(block=True)

        # run B: crash at step 21 (after the step-20 checkpoint), restore
        b = _loop(tmp_path / "b", ckpt_every=10)
        b.init_state()
        with pytest.raises(RuntimeError):
            b.run(30, fail_at=21)
        b.manager.wait()   # let the in-flight async step-20 save land
        b2 = _loop(tmp_path / "b", ckpt_every=10)
        resumed_from = b2.restore_or_init()
        assert resumed_from == 20
        b2.run(30, log_every=30)
        b2.save(block=True)

        pa = jax.tree.leaves(a.params)
        pb = jax.tree.leaves(b2.params)
        for x, y in zip(pa, pb):
            np.testing.assert_array_equal(np.asarray(x, np.float32),
                                          np.asarray(y, np.float32))

    def test_moe_arch_trains(self):
        loop = _loop(arch="qwen3-moe-235b-a22b")
        loop.init_state()
        hist = loop.run(20, log_every=5)
        assert hist[-1]["loss"] < hist[0]["loss"]
        assert np.isfinite(hist[-1]["router_aux"])

    def test_hybrid_arch_trains(self):
        loop = _loop(arch="jamba-1.5-large-398b")
        loop.init_state()
        hist = loop.run(12, log_every=4)
        assert hist[-1]["loss"] < hist[0]["loss"] + 0.1


@pytest.mark.slow
class TestServing:
    def test_engine_completes_requests(self):
        cfg = get_config("llama3.2-1b", smoke=True)
        engine = build_engine(cfg, batch=2, max_seq=32)
        for i in range(5):
            engine.submit(Request(rid=i, prompt=[3 + i, 7, 11],
                                  max_new_tokens=4))
        finished = engine.run()
        assert len(finished) == 5
        for r in finished:
            assert 1 <= len(r.output) <= 4
            assert all(0 <= t < cfg.padded_vocab for t in r.output)

    def test_continuous_batching_reuses_slots(self):
        cfg = get_config("llama3.2-1b", smoke=True)
        engine = build_engine(cfg, batch=2, max_seq=32)
        for i in range(6):
            engine.submit(Request(rid=i, prompt=[2, 3],
                                  max_new_tokens=2 + i % 3))
        finished = engine.run()
        assert len(finished) == 6   # 6 requests through 2 slots

    def test_greedy_is_deterministic(self):
        cfg = get_config("llama3.2-1b", smoke=True)
        outs = []
        for _ in range(2):
            engine = build_engine(cfg, batch=1, max_seq=16, seed=3)
            engine.submit(Request(rid=0, prompt=[5, 9], max_new_tokens=5))
            outs.append(engine.run()[0].output)
        assert outs[0] == outs[1]

    def test_async_front_end_matches_sync(self):
        """The async scheduler (chunked pow2 prefill) must produce greedy
        outputs identical to the token-by-token sync loop, from one
        decode-step trace across mixed prompt lengths."""
        cfg = get_config("llama3.2-1b", smoke=True)
        outs = {}
        for mode in ("sync", "async"):
            engine = build_engine(cfg, batch=2, max_seq=48, seed=0,
                                  prefill_chunk=8)
            for i in range(6):
                prompt = [2 + (5 * i + j) % 20 for j in range(3 + 4 * i)]
                engine.submit(Request(rid=i, prompt=prompt,
                                      max_new_tokens=3))
            finished = (engine.run() if mode == "sync"
                        else engine.run_async())
            outs[mode] = {r.rid: list(r.output) for r in finished}
            if mode == "async":
                assert engine.compile_counts["decode_step"] == 1
                # pow2 chunking: at most log2(prefill_chunk)+1 traces
                assert 1 <= engine.compile_counts["prefill_chunk"] <= 4
        assert outs["sync"] == outs["async"]

    def test_async_submit_while_running(self):
        """Requests submitted after start() are picked up by the scheduler
        thread; drain() returns them all."""
        cfg = get_config("llama3.2-1b", smoke=True)
        engine = build_engine(cfg, batch=2, max_seq=32)
        engine.start()
        try:
            for i in range(4):
                engine.submit(Request(rid=i, prompt=[3 + i, 7, 11],
                                      max_new_tokens=2))
            finished = engine.drain(timeout=120)
        finally:
            engine.stop()
        assert sorted(r.rid for r in finished) == [0, 1, 2, 3]
        assert all(1 <= len(r.output) <= 2 for r in finished)

    def test_mamba_engine(self):
        cfg = get_config("mamba2-130m", smoke=True)
        engine = build_engine(cfg, batch=2, max_seq=16)
        engine.submit(Request(rid=0, prompt=[4, 8, 15], max_new_tokens=3))
        finished = engine.run()
        assert len(finished) == 1 and len(finished[0].output) >= 1


class TestKlaraptorIntegration:
    def test_tuned_kernels_in_model_forward(self):
        """Build a driver, register it, and run a Pallas-enabled forward:
        ops.matmul must consult the driver (paper step 6)."""
        registry.clear()
        sim = V5eSimulator(noise=0.03, seed=2)
        kl = Klaraptor(sim)
        build = kl.build_driver(matmul_spec(dtype_bytes=4), repeats=2,
                                max_configs_per_size=12)
        # spec name is matmul_b32 (f32); ops.matmul consults it for f32 inputs
        from repro.kernels import ops
        x = jnp.asarray(np.random.RandomState(0).randn(256, 256), jnp.float32)
        w = jnp.asarray(np.random.RandomState(1).randn(256, 256), jnp.float32)
        out = ops.matmul(x, w, use_pallas=True, interpret=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(x @ w),
                                   atol=1e-3)
        hist = build.driver.namespace["_HISTORY"]
        assert ((256, 256, 256) in hist), hist  # decision was consulted
        registry.clear()
