"""Driver-artifact cache tests: hit, miss, stale-hash invalidation, and the
cross-process warm start (a driver built in one process is loaded -- not
rebuilt -- by a fresh process via choose_or_default)."""

import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core import (DriverCache, Klaraptor, V5eSimulator, cache_key,
                        matmul_spec)
from repro.core.driver import registry, warm_start_from_cache

SRC = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")


@pytest.fixture()
def cache_dir(tmp_path, monkeypatch):
    d = tmp_path / "cache"
    monkeypatch.setenv("KLARAPTOR_CACHE_DIR", str(d))
    registry.clear()
    yield str(d)
    registry.clear()


def _build(register=False, **kw):
    sim = V5eSimulator(noise=0.03, seed=5)
    kl = Klaraptor(sim)
    return kl, kl.build_driver(matmul_spec(), repeats=2,
                               max_configs_per_size=16, register=register,
                               **kw)


class TestCacheStore:
    def test_miss_then_hit(self, cache_dir):
        kl, first = _build()
        assert not first.from_cache
        assert first.collected.n_probe_executions > 0
        # identical build inputs: second build must come from the store,
        # probe nothing, and produce the identical driver program
        kl2, second = _build()
        assert second.from_cache
        assert second.probe_device_seconds == 0.0
        assert second.driver.source == first.driver.source
        D = {"m": 4096, "n": 4096, "k": 4096}
        assert second.driver.choose(D) == first.driver.choose(D)
        # fitted functions survive serialization
        for m, f in second.fits.items():
            assert np.isfinite(f.rel_error), m

    def test_changed_hyperparams_miss(self, cache_dir):
        _build()
        _, rebuilt = _build(seed=123)
        assert not rebuilt.from_cache

    def test_changed_spec_misses(self, cache_dir):
        _build()
        spec = matmul_spec()
        spec.constraints = spec.constraints + ("bm <= 512",)
        sim = V5eSimulator(noise=0.03, seed=5)
        res = Klaraptor(sim).build_driver(spec, repeats=2,
                                          max_configs_per_size=16,
                                          register=False)
        assert not res.from_cache

    def test_stale_hash_invalidation(self, cache_dir):
        kl, first = _build()
        cache = DriverCache()
        from repro.search import RandomStrategy
        key = cache_key(matmul_spec(), kl.hw, {
            "repeats": 2, "max_configs_per_size": 16, "seed": 0,
            "max_num_degree": 2, "max_den_degree": 2, "probe_data": None,
            "device": kl.device.fingerprint(),
            "strategy": RandomStrategy().fingerprint(), "budget": None})
        path = cache.path("matmul_b16", key)
        assert os.path.exists(path), "build must write through the cache"
        # tamper with the stored artifact: content hash no longer matches
        raw = json.load(open(path))
        raw["source"] = raw["source"] + "\n# tampered\n"
        json.dump(raw, open(path, "w"))
        assert cache.get("matmul_b16", key) is None
        assert not os.path.exists(path), "stale entry must be evicted"
        # next build treats it as a miss and rebuilds cleanly
        _, rebuilt = _build()
        assert not rebuilt.from_cache

    def test_corrupt_json_is_a_miss(self, cache_dir):
        kl, _ = _build()
        cache = DriverCache()
        entry = cache.lookup_latest("matmul_b16")
        assert entry is not None
        path = cache.path("matmul_b16", entry.key)
        with open(path, "w") as f:
            f.write("{not json")
        assert cache.lookup_latest("matmul_b16") is None


class TestWarmStart:
    def test_registry_reads_through_cache(self, cache_dir):
        _build(register=False)
        registry.clear()
        from repro.core.driver import choose_or_default
        cfg = choose_or_default("matmul_b16",
                                {"m": 2048, "n": 2048, "k": 2048},
                                {"bm": 128, "bn": 512, "bk": 512})
        # a cached driver was loaded, not the default heuristic
        assert registry.get("matmul_b16") is not None
        assert set(cfg) == {"bm", "bn", "bk"}

    def test_warm_start_from_cache_lists_kernels(self, cache_dir):
        _build(register=False)
        registry.clear()
        loaded = warm_start_from_cache()
        assert loaded == ["matmul_b16"]
        assert registry.get("matmul_b16") is not None

    def test_cross_process_round_trip(self, cache_dir):
        """Driver built here is loaded (not rebuilt) by a fresh process."""
        _, first = _build(register=False)
        expect = first.driver.choose({"m": 4096, "n": 4096, "k": 4096})
        code = textwrap.dedent("""
            import json
            from repro.core.driver import choose_or_default, registry
            assert registry.get("matmul_b16") is None   # fresh process
            cfg = choose_or_default("matmul_b16",
                                    {"m": 4096, "n": 4096, "k": 4096},
                                    {"bm": -1, "bn": -1, "bk": -1})
            loaded = registry.get("matmul_b16") is not None
            print(json.dumps({"cfg": cfg, "loaded": loaded}))
        """)
        env = dict(os.environ)
        env["KLARAPTOR_CACHE_DIR"] = cache_dir
        env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
        proc = subprocess.run([sys.executable, "-c", code],
                              capture_output=True, text=True, env=env,
                              timeout=120)
        assert proc.returncode == 0, proc.stderr
        out = json.loads(proc.stdout.strip().splitlines()[-1])
        assert out["loaded"], "fresh process must load the cached driver"
        assert out["cfg"] == expect
        assert out["cfg"] != {"bm": -1, "bn": -1, "bk": -1}


class TestFallbacks:
    def test_choose_or_default_wrong_data_params(self, cache_dir):
        """A driver built for different data params must not crash the
        untuned fallback path (KeyError/TypeError -> default config)."""
        _build(register=True)
        from repro.core.driver import choose_or_default
        default = {"bq": 512, "bkv": 512}
        got = choose_or_default("matmul_b16", {"bh": 8, "sq": 128, "skv": 128},
                                default)
        assert got == default

    def test_choose_or_default_no_driver_no_cache(self, cache_dir):
        from repro.core.driver import choose_or_default
        default = {"bm": 128, "bn": 512, "bk": 512}
        got = choose_or_default("matmul_b16", {"m": 64, "n": 64, "k": 64},
                                default)
        assert got == default
