"""KLARAPTOR pipeline tests: collection, drivers, selection quality.

The headline property (paper Fig. 1): on the simulated v5e, the driver's
chosen configuration reaches >= 85% of the exhaustive-search optimum for
most kernels/sizes, while probing only small data sizes.
"""

import numpy as np
import pytest

from repro.core import (Klaraptor, V5E, V5P, V5eSimulator, exhaustive_search,
                        flash_attention_spec, matmul_spec, moe_gmm_spec,
                        polybench_suite, selection_ratio, ssd_scan_spec)
from repro.core.driver import DriverProgram, get_driver, register_driver, \
    registry


@pytest.fixture(scope="module")
def sim():
    return V5eSimulator(noise=0.04, seed=7)


@pytest.fixture(scope="module")
def matmul_build(sim):
    kl = Klaraptor(sim)
    return kl.build_driver(matmul_spec(), repeats=2, max_configs_per_size=24,
                           register=False)


class TestPipeline:
    def test_build_produces_sound_fits(self, matmul_build):
        for m, f in matmul_build.fits.items():
            assert np.isfinite(f.rel_error), m
            assert f.rel_error < 0.5, (m, f.rel_error)

    def test_selection_quality_matmul(self, sim, matmul_build):
        ratios = []
        for n in (2048, 4096, 8192):
            r = selection_ratio(matmul_spec(), sim, matmul_build.driver,
                                {"m": n, "n": n, "k": n})
            ratios.append(r["ratio"])
        # Fig. 1 criterion: >= 85% of optimal counts as good.
        assert np.median(ratios) >= 0.85, ratios

    def test_extrapolates_beyond_probe_sizes(self, sim, matmul_build):
        # probes ran at <= 1024; selection at 16k must still be sane
        r = selection_ratio(matmul_spec(), sim, matmul_build.driver,
                            {"m": 16384, "n": 16384, "k": 16384})
        assert r["ratio"] >= 0.7, r

    def test_history_memoization(self, matmul_build):
        d = matmul_build.driver
        D = {"m": 4096, "n": 4096, "k": 4096}
        first = d.choose(D)
        assert d.namespace["_HISTORY"]
        assert d.choose(D) == first

    def test_system_time_vs_exhaustive(self, sim, matmul_build):
        """Fig. 3: the tool's device-time budget (probing) is orders of
        magnitude below exhaustively running every config at target sizes."""
        probe_s = matmul_build.probe_device_seconds
        exhaustive_s = 0.0
        for n in (2048, 4096, 8192):
            _, _, _, total = exhaustive_search(matmul_spec(), sim,
                                               {"m": n, "n": n, "k": n})
            exhaustive_s += total
        assert probe_s < exhaustive_s / 10.0, (probe_s, exhaustive_s)


class TestOtherKernels:
    @pytest.mark.parametrize("spec_fn,D", [
        (flash_attention_spec,
         {"bh": 64, "sq": 8192, "skv": 8192}),
        (moe_gmm_spec, {"e": 8, "g": 4096, "k": 4096, "n": 1536}),
    ])
    def test_selection_quality(self, sim, spec_fn, D):
        spec = spec_fn()
        kl = Klaraptor(sim)
        build = kl.build_driver(spec, repeats=2, max_configs_per_size=24,
                                register=False)
        r = selection_ratio(spec, sim, build.driver, D)
        assert r["ratio"] >= 0.7, r

    def test_ssd_chunk_tuning(self, sim):
        spec = ssd_scan_spec()
        kl = Klaraptor(sim)
        build = kl.build_driver(
            spec, probe_data=[{"bh": 8, "s": 2048, "chunkflops": 1},
                              {"bh": 8, "s": 4096, "chunkflops": 1}],
            repeats=2, register=False)
        r = selection_ratio(spec, sim, build.driver,
                            {"bh": 48, "s": 65536, "chunkflops": 1})
        assert r["ratio"] >= 0.7, r


class TestPerformancePortability:
    def test_different_device_different_choice_possible(self, sim):
        """Optimal configs may differ across devices (paper Section I);
        drivers built for v5e and v5p must at minimum each stay near-optimal
        on their own device."""
        spec = matmul_spec()
        kl_e = Klaraptor(V5eSimulator(V5E, noise=0.03, seed=1))
        kl_p = Klaraptor(V5eSimulator(V5P, noise=0.03, seed=1))
        b_e = kl_e.build_driver(spec, repeats=2, max_configs_per_size=16,
                                register=False)
        b_p = kl_p.build_driver(spec, repeats=2, max_configs_per_size=16,
                                register=False)
        D = {"m": 4096, "n": 4096, "k": 4096}
        r_e = selection_ratio(spec, kl_e.device, b_e.driver, D, hw=V5E)
        r_p = selection_ratio(spec, kl_p.device, b_p.driver, D, hw=V5P)
        assert r_e["ratio"] >= 0.8 and r_p["ratio"] >= 0.8


class TestDriverProgram:
    def test_generated_source_is_self_contained(self, matmul_build, tmp_path):
        p = tmp_path / "driver.py"
        matmul_build.driver.save(str(p))
        src = p.read_text()
        assert "import numpy" in src and "def choose" in src
        loaded = DriverProgram.load("matmul_b16", str(p))
        D = {"m": 2048, "n": 2048, "k": 2048}
        assert loaded.choose(D) == matmul_build.driver.choose(D)

    def test_no_per_config_loop_in_generated_driver(self, matmul_build):
        """The emitted choose/estimate/candidates evaluate the whole table in
        ndarray passes -- no for/while loop *statement* over configurations
        survives (comprehensions over the handful of param names are fine)."""
        import re
        src = matmul_build.driver.source
        for fn in ("def candidates", "def choose", "def estimate"):
            start = src.index(fn)
            end = src.find("\ndef ", start + 1)
            body = src[start:end if end != -1 else len(src)]
            loops = re.findall(r"^\s*(for|while)\b.*:\s*$", body, re.M)
            assert not loops, (fn, loops)

    def test_registry_dispatch(self, matmul_build, tmp_path, monkeypatch):
        # fresh empty cache dir: a registry miss must not fall back to disk
        monkeypatch.setenv("KLARAPTOR_CACHE_DIR", str(tmp_path / "empty"))
        registry.clear()
        assert get_driver("matmul_b16") is None
        register_driver(matmul_build.driver)
        assert get_driver("matmul_b16") is matmul_build.driver
        registry.clear()

    def test_estimate_positive_and_monotone_in_size(self, matmul_build):
        d = matmul_build.driver
        P = {"bm": 128, "bn": 512, "bk": 512}
        t1 = d.estimate({"m": 1024, "n": 1024, "k": 1024}, P)
        t2 = d.estimate({"m": 4096, "n": 4096, "k": 4096}, P)
        assert 0 < t1 < t2


class TestPolybenchSuite:
    def test_suite_covers_table1_families(self):
        suite = polybench_suite()
        for name in ("gemm", "atax_k1", "bicg_k1", "mvt_k1", "conv2d",
                     "corr", "gesummv", "syrk", "reduce",
                     "gramschmidt_k1"):
            assert name in suite
        for spec in suite.values():
            cands = spec.candidates(
                {d: 1024 for d in spec.data_params})
            assert cands, spec.name
