"""repro.introspect: automatic KernelSpec extraction from Pallas kernels.

Covers the tentpole acceptance bar: introspected specs behaviorally
identical to all four hand-written tier-1 specs (grid, candidates, traffic,
stage bytes, feasible set, chosen config at 8 representative shapes), plus
the two auto-specced kernels running the full pipeline with zero
hand-written spec code, the kernel-content cache-key invalidation, and the
hardened constraint-string evaluation (SpecError satellite).
"""

from __future__ import annotations

import numpy as np
import pytest

from repro.core import (Klaraptor, SpecError, V5eSimulator, cache_key,
                        choose_or_default, dtype_bytes, matmul_spec,
                        registry, selection_ratio)
from repro.introspect import (GridSpec, IntrospectError, auto_register,
                              capture_kernel, spec_from_kernel, trace_points)
from repro.introspect.tier1 import tier1_pairs

# 8 representative shapes per tier-1 kernel (all extents sublane-aligned,
# the lattice real serving traffic lives on).
EQUIV_SHAPES = {
    "matmul_b16": [
        {"m": 512, "n": 512, "k": 512},
        {"m": 1024, "n": 1024, "k": 1024},
        {"m": 2048, "n": 1024, "k": 4096},
        {"m": 128, "n": 8192, "k": 1024},
        {"m": 4096, "n": 4096, "k": 2048},
        {"m": 8192, "n": 256, "k": 512},
        {"m": 2048, "n": 2048, "k": 2048},
        {"m": 256, "n": 1024, "k": 8192},
    ],
    "flash_attn_d128_causal": [
        {"bh": 8, "sq": 1024, "skv": 1024},
        {"bh": 16, "sq": 2048, "skv": 2048},
        {"bh": 32, "sq": 4096, "skv": 4096},
        {"bh": 16, "sq": 2048, "skv": 8192},
        {"bh": 64, "sq": 512, "skv": 512},
        {"bh": 8, "sq": 8192, "skv": 8192},
        {"bh": 48, "sq": 1024, "skv": 4096},
        {"bh": 24, "sq": 4096, "skv": 1024},
    ],
    "moe_gmm_b16": [
        {"e": 8, "g": 1024, "k": 2048, "n": 1024},
        {"e": 4, "g": 4096, "k": 1024, "n": 2048},
        {"e": 16, "g": 512, "k": 1024, "n": 1024},
        {"e": 2, "g": 2048, "k": 4096, "n": 512},
        {"e": 8, "g": 256, "k": 512, "n": 2048},
        {"e": 32, "g": 1024, "k": 1024, "n": 1536},
        {"e": 4, "g": 8192, "k": 2048, "n": 1024},
        {"e": 8, "g": 2048, "k": 2048, "n": 2048},
    ],
    "ssd_scan_h64_n128": [
        {"bh": 8, "s": 2048, "chunkflops": 1},
        {"bh": 16, "s": 8192, "chunkflops": 1},
        {"bh": 64, "s": 65536, "chunkflops": 1},
        {"bh": 32, "s": 4096, "chunkflops": 1},
        {"bh": 8, "s": 32768, "chunkflops": 1},
        {"bh": 128, "s": 1024, "chunkflops": 1},
        {"bh": 48, "s": 16384, "chunkflops": 1},
        {"bh": 16, "s": 131072, "chunkflops": 1},
    ],
}


@pytest.fixture(scope="module")
def pairs():
    """(hand spec, introspected spec) for every tier-1 kernel."""
    out = {}
    for fn, gs, hand in tier1_pairs():
        out[hand.name] = (hand, spec_from_kernel(fn, gs))
    return out


@pytest.fixture(scope="module")
def built_pairs(pairs):
    """Drivers built from hand and introspected specs with identical probe
    settings and noise streams (no cache -- the specs fingerprint apart)."""
    out = {}
    for name, (hand, intro) in pairs.items():
        b_h = Klaraptor(V5eSimulator(noise=0.03, seed=3),
                        cache=False).build_driver(
            hand, repeats=2, max_configs_per_size=12, register=False)
        b_i = Klaraptor(V5eSimulator(noise=0.03, seed=3),
                        cache=False).build_driver(
            intro, repeats=2, max_configs_per_size=12, register=False)
        out[name] = (b_h.driver, b_i.driver)
    return out


class TestTier1Equivalence:
    @pytest.mark.parametrize("name", sorted(EQUIV_SHAPES))
    def test_structural_equivalence(self, pairs, name):
        """Same candidates, grid steps, stage bytes, and oracle times at
        every representative shape."""
        hand, intro = pairs[name]
        assert intro.data_params == hand.data_params
        assert intro.program_params == hand.program_params
        sim = V5eSimulator(noise=0.0, seed=0)
        for D in EQUIV_SHAPES[name]:
            th, ti = hand.candidates(D), intro.candidates(D)
            assert th.params == ti.params
            assert len(th) == len(ti) > 0
            for p in th.params:
                assert np.array_equal(th[p], ti[p]), (D, p)
            assert np.array_equal(hand.grid_steps_batch(D, th),
                                  intro.grid_steps_batch(D, ti))
            assert np.array_equal(hand.vmem_stage_bytes_batch(D, th),
                                  intro.vmem_stage_bytes_batch(D, ti))
            # The opaque oracle cannot tell the two specs apart: identical
            # per-config times means identical traffic, padding and FLOPs.
            t_h = sim.true_time_batch(hand.traffic_table(D, th))
            t_i = sim.true_time_batch(intro.traffic_table(D, ti))
            assert np.array_equal(t_h, t_i), D

    @pytest.mark.parametrize("name", sorted(EQUIV_SHAPES))
    def test_chosen_configs_identical(self, built_pairs, name):
        """Drivers built from the two specs choose the same config at every
        representative shape."""
        drv_h, drv_i = built_pairs[name]
        for D in EQUIV_SHAPES[name]:
            assert drv_h.choose(D) == drv_i.choose(D), D

    def test_feasibility_agrees_scalar(self, pairs):
        hand, intro = pairs["matmul_b16"]
        D = {"m": 1024, "n": 1024, "k": 1024}
        for P in ({"bm": 128, "bn": 512, "bk": 512},
                  {"bm": 8, "bn": 128, "bk": 128},
                  {"bm": 100, "bn": 512, "bk": 512}):   # misaligned bm
            assert hand.feasible(D, P) == intro.feasible(D, P), P


class TestDerivation:
    def test_flash_kv_residency(self, pairs):
        """The k/v index map's GQA arithmetic depends on the batch axis and
        the kv axis, never the query axis -- found by jaxpr data flow."""
        _, intro = pairs["flash_attn_d128_causal"]
        names = [a.name for a in intro.grid]
        k_op = intro.operands[1]
        dep_pos = sorted(names.index(d) for d in k_op.deps)
        assert dep_pos == [0, 2]

    def test_ssd_decay_fetched_per_batch_row(self, pairs):
        """The A (decay) plane's index map ignores the chunk axis: one
        fetch per batch row (block residency across the scan)."""
        _, intro = pairs["ssd_scan_h64_n128"]
        decay = intro.operands[4]
        assert decay.deps == (intro.grid[0].name,)
        assert decay.tile == (1, 128)

    def test_flops_and_alignment_derived(self, pairs):
        hand, intro = pairs["matmul_b16"]
        # flops/mxu were NOT hinted for matmul -- the cost walk found them.
        assert intro.flops_per_point == hand.flops_per_point == 2.0
        assert intro.mxu_fraction == 1.0
        assert "bm % 8 == 0" in intro.constraints
        assert "bn % 128 == 0" in intro.constraints

    def test_flash_lane_alignment_from_intermediate(self, pairs):
        """bkv is never the minor axis of any *operand* tile; only the
        (bq, bkv) score matrix inside the body makes it lane-critical."""
        _, intro = pairs["flash_attn_d128_causal"]
        assert "bkv % 128 == 0" in intro.constraints
        assert "bq % 8 == 0" in intro.constraints

    def test_trace_points_unambiguous(self):
        from repro.introspect.tier1 import moe_gmm_grid_spec
        (D1, P1), (D2, P2) = trace_points(moe_gmm_grid_spec())
        vals1 = list(D1.values()) + list(P1.values())
        assert len(set(vals1)) == len(vals1)
        assert all(P1[p] != P2[p] for p in P1)
        assert all(D1[d] != D2[d] for d in D1)

    def test_p_dependent_flops_need_hint(self):
        """ssd's chunk-quadratic FLOP density is rejected without a hint."""
        from repro.introspect.tier1 import ssd_scan_grid_spec
        from repro.kernels.ssd_scan import ssd_scan_pallas

        gs = ssd_scan_grid_spec()
        gs.flops_per_point = None
        with pytest.raises(IntrospectError, match="flops_per_point"):
            spec_from_kernel(ssd_scan_pallas, gs)


class TestSourceFingerprint:
    def test_stable_across_traces(self):
        from repro.kernels.reduce import colsum_grid_spec, colsum_pallas
        s1 = spec_from_kernel(colsum_pallas, colsum_grid_spec())
        s2 = spec_from_kernel(colsum_pallas, colsum_grid_spec())
        assert s1.source_fingerprint == s2.source_fingerprint

    def test_changed_kernel_body_changes_cache_key(self):
        """Editing the kernel body (here: eps) must route to fresh tuning
        artifacts: different source fingerprint -> different cache key."""
        from repro.core import V5E
        from repro.kernels.layernorm import (layernorm_grid_spec,
                                             layernorm_pallas)

        s1 = spec_from_kernel(layernorm_pallas, layernorm_grid_spec(512))
        s2 = spec_from_kernel(layernorm_pallas,
                              layernorm_grid_spec(512, eps=1e-3))
        assert s1.source_fingerprint != s2.source_fingerprint
        hyper = {"repeats": 2}
        assert cache_key(s1, V5E, hyper) != cache_key(s2, V5E, hyper)

    def test_hand_spec_fingerprint_unset(self):
        assert matmul_spec().source_fingerprint == ""


class TestAutoKernelPipeline:
    def test_end_to_end_zero_hand_spec(self, tmp_path, monkeypatch):
        """introspect -> collect/fit -> choose -> plan-table dispatch ->
        telemetry, for both auto kernels, no hand-written spec anywhere."""
        monkeypatch.setenv("KLARAPTOR_CACHE_DIR", str(tmp_path))
        registry.clear()
        from repro.core.plan import precompile_plans
        from repro.launch.serve import build_auto_kernels
        from repro.telemetry import Telemetry

        sim = V5eSimulator(noise=0.03, seed=5)
        kernels = build_auto_kernels(d_model=512, tune_device=sim)
        assert [ak.name for ak in kernels] == \
            ["layernorm_c512_b16", "colsum_b16"]
        tel = Telemetry([ak.spec for ak in kernels], sim, seed=0)
        tel.install()
        try:
            for ak in kernels:
                D = ({"r": 4096} if "layernorm" in ak.name
                     else {"r": 4096, "c": 2048})
                from repro.core.driver import get_driver
                drv = get_driver(ak.name)
                assert drv is not None
                r = selection_ratio(ak.spec, sim, drv, D)
                assert r["ratio"] >= 0.7, r
                summary = precompile_plans({ak.name: ak.plan_envelope()})
                assert summary["entries"] > 0
                before = registry.stats()["plan_hits"]
                cfg = choose_or_default(ak.name, D, ak.defaults)
                assert registry.stats()["plan_hits"] == before + 1
                assert cfg == drv.choose(D)
            import json
            j = json.loads(tel.exporter.json())
            assert j["counters"]["choices_by_source"].get("plan", 0) >= 2
        finally:
            tel.uninstall()
            registry.clear()

    def test_fit_config_uses_derived_alignment(self):
        from repro.kernels.reduce import colsum_grid_spec, colsum_pallas
        ak = auto_register(colsum_pallas, colsum_grid_spec())
        assert ak.alignments() == {"br": 8, "bc": 128}
        fitted = ak.fit_config({"br": 512, "bc": 1024}, {"r": 384, "c": 640})
        assert 384 % fitted["br"] == 0 and fitted["br"] % 8 == 0
        assert 640 % fitted["bc"] == 0 and fitted["bc"] % 128 == 0

    def test_auto_register_idempotent(self):
        from repro.introspect import auto_kernels, get_auto
        from repro.kernels.reduce import colsum_grid_spec, colsum_pallas
        a1 = auto_register(colsum_pallas, colsum_grid_spec())
        a2 = auto_register(colsum_pallas, colsum_grid_spec())
        assert a1 is a2
        assert get_auto(a1.name) is a1
        assert a1.name in auto_kernels()

    def test_ops_dispatch_interpret_correct(self):
        """The auto-specced ops produce correct numerics through the full
        dispatch path (default config, no tuning) in interpret mode."""
        import jax
        import jax.numpy as jnp

        from repro.kernels import ops, ref

        x = jax.random.normal(jax.random.PRNGKey(0), (64, 256), jnp.float32)
        res = jax.random.normal(jax.random.PRNGKey(1), (64, 256), jnp.float32)
        g = jax.random.normal(jax.random.PRNGKey(2), (256,), jnp.float32)
        b = jax.random.normal(jax.random.PRNGKey(3), (256,), jnp.float32)
        y = ops.layernorm(x, res, g, b, use_pallas=True, interpret=True)
        np.testing.assert_allclose(
            np.asarray(y), np.asarray(ref.layernorm_ref(x, res, g, b)),
            atol=1e-5)
        s = ops.blocked_colsum(x, use_pallas=True, interpret=True)
        np.testing.assert_allclose(
            np.asarray(s), np.asarray(ref.colsum_ref(x)), rtol=1e-5)


class TestSpecErrorHardening:
    def test_unknown_symbol_named(self):
        spec = matmul_spec()
        spec.constraints = spec.constraints + ("bm <= vmen",)   # typo
        with pytest.raises(SpecError, match="vmen"):
            spec.candidates({"m": 512, "n": 512, "k": 512})

    def test_syntax_error_diagnosed(self):
        spec = matmul_spec()
        spec.constraints = ("bm <=",)
        with pytest.raises(SpecError, match="not a valid Python expression"):
            spec.candidates({"m": 512, "n": 512, "k": 512})

    def test_no_builtins_in_namespace(self):
        spec = matmul_spec()
        spec.constraints = ("len([bm]) == 1",)
        with pytest.raises(SpecError, match="'len'"):
            spec.candidates({"m": 512, "n": 512, "k": 512})

    def test_math_and_np_still_allowed(self):
        spec = matmul_spec()
        spec.constraints = spec.constraints + (
            "bm <= math.inf", "np.maximum(bm, 8) >= 8")
        table = spec.candidates({"m": 512, "n": 512, "k": 512})
        assert len(table) > 0


class TestDtypeTableDedup:
    def test_single_canonical_table(self):
        from repro.analysis import hlo
        from repro.core import device_model
        assert hlo.DTYPE_BYTES is device_model.DTYPE_BYTES

    def test_dtype_bytes_lookups(self):
        import jax.numpy as jnp
        assert dtype_bytes("bf16") == 2
        assert dtype_bytes(jnp.bfloat16) == 2
        assert dtype_bytes(np.float32) == 4
        assert dtype_bytes(np.dtype("int8")) == 1

    def test_introspected_dtypes_from_table(self, pairs):
        _, intro = pairs["ssd_scan_h64_n128"]
        assert [op.dtype_bytes for op in intro.operands] == \
            [2, 4, 2, 2, 4, 2, 4]


class TestIntrospectErrors:
    def test_not_a_pallas_kernel(self):
        import jax.numpy as jnp

        gs = GridSpec(
            name="plain_fn", data_params=("n",), program_params=("b",),
            make_args=lambda D: (
                __import__("jax").ShapeDtypeStruct((D["n"],), jnp.float32),))
        with pytest.raises(IntrospectError, match="pallas_call"):
            spec_from_kernel(lambda x, b: x * 2, gs)

    def test_capture_reports_scratch(self):
        from repro.introspect.tier1 import flash_attention_grid_spec
        from repro.kernels.flash_attention import flash_attention_pallas

        gs = flash_attention_grid_spec()
        (D1, P1), _ = trace_points(gs)
        cap = capture_kernel(flash_attention_pallas, gs, D1, P1)
        assert sum(op.is_scratch for op in cap.operands) == 3
        assert sum(op.is_output for op in cap.operands) == 1
