"""Distribution tests: sharding rules, collectives, PP, elastic restore.

Multi-device cases run in subprocesses with fake CPU devices so this
process keeps its single-device view (see conftest).
"""

import numpy as np
import pytest

from repro.distributed.sharding import Sharder, decode_rules, train_rules
from repro.distributed.fault_tolerance import (StragglerMonitor, Watchdog,
                                               retry_loop,
                                               FaultToleranceError)
from repro.distributed.pipeline_parallel import bubble_fraction


class TestSharderRules:
    def test_pspec_divisibility_fallback(self):
        # no mesh: everything replicated
        sh = Sharder(mesh=None)
        assert sh.dp_size() == 1

    def test_train_rules_have_core_axes(self):
        r = train_rules()
        assert r["batch"] == ("pod", "data")
        assert r["heads"] == "model"
        assert r["vocab"] == "model"
        assert r["act_seq"] == "model"       # sequence parallelism default

    def test_decode_rules_modes(self):
        assert decode_rules("heads")["cache_heads"] == "model"
        assert decode_rules("seq")["cache_seq"] == "model"
        long = decode_rules("long")
        assert long["cache_seq"] == ("data", "model")
        assert long["batch"] is None


@pytest.mark.slow
class TestMeshSharding:
    def test_pspec_on_real_mesh(self, devices8):
        devices8("""
            import jax
            from jax.sharding import PartitionSpec as P
            from repro.distributed.sharding import Sharder, train_rules
            from repro.launch.mesh import make_mesh
            mesh = make_mesh((2, 4), ("data", "model"))
            sh = Sharder(mesh=mesh, rules=train_rules(fsdp=True))
            # divisible dims shard; indivisible fall back to replication
            ps = sh.pspec((8, 512), ("embed", "heads"))
            assert ps == P("data", "model"), ps
            ps2 = sh.pspec((7, 512), ("embed", "heads"))
            assert ps2 == P(None, "model"), ps2
            assert ("embed", "data", 7) in sh.dropped
            # same mesh axis never used twice
            ps3 = sh.pspec((8, 8), ("experts", "mlp"))
            assert ps3 == P("model", None), ps3
            print("ok")
        """)

    def test_train_step_executes_on_mesh(self, devices8):
        devices8("""
            import jax, jax.numpy as jnp, numpy as np
            from repro.configs import get_config
            from repro.configs.base import ShapePreset
            from repro.launch.mesh import make_mesh
            from repro.launch.steps import build_step
            from repro.models import init_params
            from repro.optim import adamw_init

            cfg = get_config("llama3.2-1b", smoke=True)
            mesh = make_mesh((2, 4), ("data", "model"))
            preset = ShapePreset("t", "train", 64, 4)
            bundle = build_step(cfg, preset, mesh)
            with mesh:
                params = init_params(bundle.model.specs(),
                                     jax.random.PRNGKey(0))
                from repro.launch.steps import _opt_cfg_for
                opt = adamw_init(_opt_cfg_for(cfg), params)
                toks = jnp.asarray(np.random.randint(
                    0, cfg.vocab_size, (4, 65)), jnp.int32)
                step = jax.jit(bundle.fn,
                               in_shardings=bundle.in_shardings,
                               out_shardings=bundle.out_shardings)
                p2, o2, m = step(params, opt, {"tokens": toks})
                loss1 = float(m["loss"])
                p3, o3, m2 = step(p2, o2, {"tokens": toks})
                loss2 = float(m2["loss"])
            assert np.isfinite(loss1) and np.isfinite(loss2)
            assert loss2 < loss1   # two steps on same batch must descend
            print("ok", loss1, loss2)
        """, timeout=420)

    def test_hierarchical_and_compressed_pmean(self, devices8):
        devices8("""
            import jax, jax.numpy as jnp, numpy as np
            from jax.sharding import PartitionSpec as P
            from repro.distributed.collectives import (hierarchical_pmean,
                                                       compressed_pmean)
            from repro.launch.mesh import make_mesh
            mesh = make_mesh((2, 4), ("pod", "data"))
            x = jnp.arange(8.0).reshape(8, 1) * jnp.ones((8, 16))

            from repro.distributed.collectives import shard_map_compat

            def f(x):
                return hierarchical_pmean({"g": x}, "data", "pod")["g"]
            out = jax.jit(shard_map_compat(f, mesh=mesh,
                          in_specs=P(("pod","data")), out_specs=P()))(x)
            np.testing.assert_allclose(np.asarray(out), 3.5)

            def g(x):
                m, r = compressed_pmean({"g": x}, "data", "pod")
                return m["g"]
            out2 = jax.jit(shard_map_compat(g, mesh=mesh,
                           in_specs=P(("pod","data")), out_specs=P()))(x)
            # int8 quantization: within one quant step of the true mean
            assert abs(float(out2[0,0]) - 3.5) < 0.1, float(out2[0,0])
            print("ok")
        """)

    def test_gpipe_matches_sequential(self, devices8):
        devices8("""
            import jax, jax.numpy as jnp, numpy as np
            from repro.distributed.pipeline_parallel import gpipe_forward
            n_stages, n_micro, mb, dim = 4, 8, 2, 16
            from repro.launch.mesh import make_mesh
            mesh = make_mesh((4,), ("pipe",))
            rng = np.random.RandomState(0)
            ws = jnp.asarray(rng.randn(n_stages, dim, dim) * 0.3,
                             jnp.float32)
            x = jnp.asarray(rng.randn(n_micro, mb, dim), jnp.float32)

            def stage_fn(w, h):
                return jnp.tanh(h @ w)

            out = gpipe_forward(stage_fn, ws, x, mesh, axis="pipe")
            # sequential reference
            ref = x
            for s in range(n_stages):
                ref = jnp.tanh(ref @ ws[s])
            np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                       atol=1e-5)
            print("ok")
        """)

    def test_elastic_restore_across_meshes(self, devices8):
        devices8("""
            import jax, jax.numpy as jnp, numpy as np, tempfile
            from repro.checkpoint import CheckpointManager
            from repro.distributed.elastic import (elastic_restore,
                                                   shardings_for_specs)
            from repro.distributed.sharding import Sharder, train_rules
            from repro.models.module import ParamSpec, init_params

            specs = {"w": ParamSpec((8, 16), jnp.float32,
                                    ("embed", "heads"))}
            d = tempfile.mkdtemp()
            mgr = CheckpointManager(d, async_save=False)

            from repro.launch.mesh import make_mesh
            mesh1 = make_mesh((2, 4), ("data", "model"))
            sh1 = Sharder(mesh=mesh1, rules=train_rules())
            params = init_params(specs, jax.random.PRNGKey(0))
            params = jax.device_put(params, shardings_for_specs(specs, sh1))
            mgr.save(1, params)

            # restore onto a DIFFERENT mesh shape (4x2)
            mesh2 = make_mesh((4, 2), ("data", "model"))
            sh2 = Sharder(mesh=mesh2, rules=train_rules())
            restored, _, step = elastic_restore(
                mgr, specs, sh2,
                {"w": jax.ShapeDtypeStruct((8, 16), jnp.float32)})
            np.testing.assert_array_equal(np.asarray(restored["w"]),
                                          np.asarray(params["w"]))
            assert restored["w"].sharding.mesh.shape["data"] == 4
            print("ok")
        """)


class TestFaultTolerance:
    def test_watchdog_fires(self):
        w = Watchdog(timeout_s=0.2).start()
        import time
        time.sleep(0.5)
        assert w.fired
        w.stop()

    def test_watchdog_beats_keep_alive(self):
        import time
        w = Watchdog(timeout_s=0.4).start()
        for _ in range(4):
            time.sleep(0.1)
            w.beat()
        assert not w.fired
        w.stop()

    def test_watchdog_fires_once_until_reset(self):
        import time
        fires = []
        w = Watchdog(timeout_s=0.15, on_timeout=lambda: fires.append(1))
        w.start()
        time.sleep(0.5)
        # fired exactly once despite several timeout windows elapsing
        assert w.fired and len(fires) == 1
        w.stop()

    def test_watchdog_rearms_after_reset(self):
        import time
        fires = []
        w = Watchdog(timeout_s=0.15, on_timeout=lambda: fires.append(1))
        w.start()
        time.sleep(0.4)
        assert w.fired and len(fires) == 1
        w.reset()               # re-arm: a revived worker is watchable again
        assert not w.fired
        time.sleep(0.1)
        assert not w.fired      # reset also refreshed the heartbeat
        time.sleep(0.4)
        assert w.fired and len(fires) == 2
        w.stop()

    def test_straggler_monitor(self):
        mon = StragglerMonitor(n_hosts=4, threshold=2.0, patience=2)
        assert mon.observe([1.0, 1.0, 1.0, 1.0]) == []
        assert mon.observe([1.0, 1.0, 1.0, 5.0]) == []
        assert mon.observe([1.0, 1.0, 1.0, 5.0]) == [3]

    def test_retry_loop_survives_failures(self):
        calls = {"n": 0}

        def run(start):
            calls["n"] += 1
            if calls["n"] < 3:
                raise RuntimeError("boom")

        failures = retry_loop(run, restore_fn=lambda: 0, max_failures=5)
        assert failures == 2

    def test_retry_loop_gives_up(self):
        def run(start):
            raise RuntimeError("always")

        with pytest.raises(FaultToleranceError):
            retry_loop(run, restore_fn=lambda: 0, max_failures=2)

    def test_bubble_fraction(self):
        assert bubble_fraction(4, 12) == pytest.approx(3 / 15)
