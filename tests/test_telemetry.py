"""repro.telemetry tests: recorder primitives, drift thresholds, the full
drift -> refit -> hot-swap path (budgets never exceeded, cache version bumps
picked up by a fresh "process"), exporter determinism, and the satellite
hardening (search-memo scoping by strategy/budget, corrupted-cache-entry
tolerance at warm start)."""

import dataclasses
import json
import logging

import numpy as np
import pytest

from repro.core import (CandidateTable, Klaraptor, V5E, V5P, V5eSimulator,
                        matmul_spec, registry, selection_ratio)
from repro.core.cache import CacheEntry, DriverCache
from repro.core.driver import (ChoiceEvent, choose_or_default,
                               get_choice_listener, set_choice_listener,
                               warm_start_from_cache)
from repro.search import SearchBudget
from repro.telemetry import (DriftDetector, LaunchRecorder, RingBuffer,
                             Telemetry, TelemetryConfig, refit_probe_shapes,
                             scale_budget, shape_bucket)

D_SMALL = {"m": 1024, "n": 1024, "k": 1024}
MM_DEFAULT = {"bm": 128, "bn": 512, "bk": 512}


@pytest.fixture()
def clean(tmp_path, monkeypatch):
    """Isolated cache dir, empty registry, no leftover choice listener."""
    monkeypatch.setenv("KLARAPTOR_CACHE_DIR", str(tmp_path / "cache"))
    registry.clear()
    set_choice_listener(None)
    yield str(tmp_path / "cache")
    set_choice_listener(None)
    registry.clear()


def _event(D, predicted=1e-3, source="driver", kernel="matmul_b16",
           config=None):
    return ChoiceEvent(kernel=kernel, D=dict(D),
                       config=config or dict(MM_DEFAULT), source=source,
                       predicted_s=predicted, hw_name=V5E.name)


def _corrupted_build(register=True, seed=7):
    """Driver fit against v5p physics masquerading as v5e: warm-starts on
    v5e but mispredicts it (the 'stale/mis-fit driver' of the issue)."""
    fake_hw = dataclasses.replace(V5P, name=V5E.name)
    kl = Klaraptor(V5eSimulator(fake_hw, noise=0.04, seed=seed), hw=fake_hw)
    return kl.build_driver(matmul_spec(), repeats=2, max_configs_per_size=16,
                           seed=seed, register=register)


class TestRecorderPrimitives:
    def test_shape_bucket_is_log2_and_order_insensitive(self):
        assert shape_bucket({"m": 1024, "n": 1500}) == \
            shape_bucket({"n": 1500, "m": 1024})
        b = dict(shape_bucket({"m": 1024, "n": 1500, "e": 1}))
        assert b == {"m": 10, "n": 11, "e": 0}
        # 1024 and 1500 differ; 1024 and 4096 differ; 513..1024 share
        assert dict(shape_bucket({"m": 513}))["m"] == 10
        assert dict(shape_bucket({"m": 4096}))["m"] == 12

    def test_ring_buffer_wraps_oldest_first(self):
        rb = RingBuffer(3)
        for x in (1.0, 2.0):
            rb.push(x)
        assert len(rb) == 2 and list(rb.values()) == [1.0, 2.0]
        for x in (3.0, 4.0):
            rb.push(x)
        assert len(rb) == 3 and list(rb.values()) == [2.0, 3.0, 4.0]
        assert rb.total_pushed == 4

    def test_recorder_samples_first_then_every_nth(self):
        rec = LaunchRecorder(TelemetryConfig(probe_every=3))
        probes = [rec.observe_choice(_event(D_SMALL))[1] for _ in range(7)]
        assert probes == [True, False, False, True, False, False, True]
        # choices without a prediction are never probe-eligible
        _, p = rec.observe_choice(_event(D_SMALL, predicted=None,
                                         source="default"))
        assert p is False

    def test_scale_budget_slices_never_sum_past_total(self):
        for total in (100, 7, 2, 1):
            b = SearchBudget(max_executions=total, max_device_seconds=2.0)
            parts = [scale_budget(b, f) for f in (0.45, 0.5, 0.05)]
            assert sum(p.max_executions for p in parts) <= total
        assert sum(p.max_device_seconds for p in parts) <= 2.0 + 1e-12

    def test_refit_budget_slices_sum_exactly_to_total(self):
        from repro.telemetry import RefitController
        kl = Klaraptor(V5eSimulator(noise=0.03, seed=5), cache=False)
        ctl = RefitController(kl)
        for total in (200, 7, 2, 1):
            parts = ctl._budgets(SearchBudget(max_executions=total))
            assert sum(p.max_executions for p in parts) == total

    def test_refit_probe_shapes_live_ray(self):
        shapes = refit_probe_shapes({"m": 4096, "k": 4096, "e": 1})
        assert shapes[0] == {"m": 4096, "k": 4096, "e": 1}
        assert {"m": 2048, "k": 2048, "e": 1} in shapes
        assert all(s["e"] == 1 for s in shapes)   # never collapses below 1


class TestDriftDetector:
    def _loop(self, rel_err, n, cfg):
        rec = LaunchRecorder(cfg)
        det = DriftDetector(cfg)
        events = []
        for _ in range(n):
            stats, _ = rec.observe_choice(_event(D_SMALL))
            rec.record_probe(stats, 1e-3, 1e-3 * (1.0 + rel_err))
            events.append(det.update(stats))
        return events

    def test_no_fire_below_threshold(self):
        cfg = TelemetryConfig(drift_threshold=0.25, min_samples=3,
                              probe_every=1)
        assert all(e is None for e in self._loop(0.1, 8, cfg))

    def test_fires_only_after_min_samples(self):
        cfg = TelemetryConfig(drift_threshold=0.25, min_samples=3,
                              probe_every=1)
        events = self._loop(0.8, 4, cfg)
        assert events[0] is None and events[1] is None
        assert events[2] is not None
        assert events[2].rel_error_ewma > 0.25
        assert events[2].D == D_SMALL

    def test_cooldown_and_circuit_breaker(self):
        cfg = TelemetryConfig(drift_threshold=0.25, min_samples=1,
                              probe_every=1, cooldown_choices=5,
                              max_refits_per_key=2)
        events = self._loop(0.8, 14, cfg)
        fired = [i for i, e in enumerate(events) if e is not None]
        assert fired[0] == 0
        assert fired[1] - fired[0] >= 5            # cooldown respected
        assert len(fired) == 2                     # circuit breaker

    def test_monitoring_mode_keeps_reporting_drift(self):
        """refit_enabled=False must record drift events forever (cooldown-
        rate-limited), not stop after max_refits_per_key firings."""
        cfg = TelemetryConfig(drift_threshold=0.25, min_samples=1,
                              probe_every=1, cooldown_choices=2,
                              max_refits_per_key=2, refit_enabled=False)
        events = self._loop(0.8, 12, cfg)
        fired = [i for i, e in enumerate(events) if e is not None]
        assert len(fired) > 2                      # breaker not engaged


class TestSearchMemoScoping:
    """Satellite: the per-shape search memo is keyed by strategy + budget,
    so switching strategies or raising the budget at runtime re-searches
    instead of being silently ignored."""

    class CountingSim(V5eSimulator):
        def __init__(self, *a, **k):
            super().__init__(*a, **k)
            self.probe_rows_calls = 0

        def probe_rows(self, table, rng, repeats=1):
            self.probe_rows_calls += 1
            return super().probe_rows(table, rng, repeats)

    def test_strategy_and_budget_scope_the_memo(self, clean):
        sim = self.CountingSim(noise=0.03, seed=5)
        spec = matmul_spec()
        kw = dict(spec=spec, device=sim)

        choose_or_default(spec.name, D_SMALL, MM_DEFAULT, strategy="random",
                          budget=SearchBudget(max_executions=16), **kw)
        n1 = sim.probe_rows_calls
        assert n1 > 0
        # identical strategy+budget: memoized, no new probes
        choose_or_default(spec.name, D_SMALL, MM_DEFAULT, strategy="random",
                          budget=SearchBudget(max_executions=16), **kw)
        assert sim.probe_rows_calls == n1
        # different strategy: fresh search
        choose_or_default(spec.name, D_SMALL, MM_DEFAULT, strategy="lhs",
                          budget=SearchBudget(max_executions=16), **kw)
        n2 = sim.probe_rows_calls
        assert n2 > n1
        # raised budget: fresh search
        choose_or_default(spec.name, D_SMALL, MM_DEFAULT, strategy="random",
                          budget=SearchBudget(max_executions=48), **kw)
        assert sim.probe_rows_calls > n2


class TestWarmStartTolerance:
    """Satellite: one bad cached artifact must not take down a serving
    process at startup -- one-time warning, then skip."""

    def _put_bad_entry(self, kernel="matmul_b16"):
        cache = DriverCache()
        cache.put(CacheEntry(
            kernel=kernel, key="0" * 64,
            source="def broken(:\n",          # valid hash, invalid python
            fits={}, stats={}, created_at=1.0, hw_name=V5E.name))
        return cache

    def test_warm_start_skips_and_warns_once(self, clean, caplog,
                                             monkeypatch):
        import repro.core.driver as driver_mod
        monkeypatch.setattr(driver_mod, "_bad_entry_warned", False)
        self._put_bad_entry()
        with caplog.at_level(logging.WARNING, logger="repro.core.driver"):
            assert warm_start_from_cache() == []
            assert warm_start_from_cache() == []      # second call: silent
        warns = [r for r in caplog.records
                 if "failed to load" in r.message]
        assert len(warns) == 1

    def test_choose_or_default_survives_bad_entry(self, clean, monkeypatch):
        import repro.core.driver as driver_mod
        monkeypatch.setattr(driver_mod, "_bad_entry_warned", False)
        self._put_bad_entry()
        got = choose_or_default("matmul_b16", D_SMALL, MM_DEFAULT)
        assert got == MM_DEFAULT


class TestCacheVersioning:
    def test_lookup_prefers_higher_tuning_version(self, clean):
        cache = DriverCache()
        old = CacheEntry(kernel="k", key="a" * 64, source="S0", fits={},
                         stats={}, created_at=100.0, hw_name=V5E.name)
        new = CacheEntry(kernel="k", key="b" * 64, source="S1", fits={},
                         stats={}, created_at=1.0,      # older timestamp!
                         hw_name=V5E.name, tuning_version=1)
        cache.put(old)
        cache.put(new)
        assert cache.latest_version("k", V5E.name) == 1
        assert cache.lookup_latest("k", V5E.name).source == "S1"

    def test_invalidate_below_version(self, clean):
        cache = DriverCache()
        for i, key in enumerate(("a" * 64, "b" * 64, "c" * 64)):
            cache.put(CacheEntry(kernel="k", key=key, source=f"S{i}",
                                 fits={}, stats={}, created_at=float(i),
                                 hw_name=V5E.name, tuning_version=i))
        assert cache.invalidate("k", V5E.name, below_version=2) == 2
        assert cache.lookup_latest("k", V5E.name).tuning_version == 2
        assert cache.latest_version("k", V5E.name) == 2

    def test_tampered_version_is_evicted(self, clean):
        cache = DriverCache()
        entry = CacheEntry(kernel="k", key="a" * 64, source="S", fits={},
                           stats={}, created_at=1.0, hw_name=V5E.name,
                           tuning_version=1)
        path = cache.put(entry)
        raw = json.load(open(path))
        raw["tuning_version"] = 99          # pin a stale fit as newest
        json.dump(raw, open(path, "w"))
        assert cache.lookup_latest("k", V5E.name) is None


class TestClosedLoop:
    """Tentpole: corrupted fit -> drift detected -> budget-capped refit ->
    hot swap -> versioned write-through picked up by a fresh registry."""

    @pytest.fixture()
    def loop(self, clean):
        corrupted = _corrupted_build()
        sim = V5eSimulator(noise=0.04, seed=11)
        budget = SearchBudget(max_executions=160, max_device_seconds=1.0)
        tel = Telemetry([matmul_spec()], sim, seed=3, config=TelemetryConfig(
            probe_every=2, refit_budget=budget)).install()
        for _ in range(24):
            choose_or_default("matmul_b16", D_SMALL, MM_DEFAULT)
            if tel.refits:
                break
        yield tel, sim, corrupted, budget
        tel.uninstall()

    def test_drift_detected_and_refit_runs(self, loop):
        tel, sim, corrupted, _ = loop
        assert len(tel.drift_events) == 1
        drift = tel.drift_events[0]
        assert drift.kernel == "matmul_b16"
        assert drift.rel_error_ewma > tel.config.drift_threshold
        assert len(tel.refits) == 1 and tel.refits[0].succeeded

    def test_refit_budget_never_exceeded(self, loop):
        tel, _, _, budget = loop
        r = tel.refits[0]
        assert r.total_executions <= budget.max_executions
        assert r.total_device_seconds <= budget.max_device_seconds
        # every component is itself bounded by its slice
        assert r.search_device_seconds <= budget.max_device_seconds
        assert r.fit_device_seconds <= budget.max_device_seconds

    def test_hot_swap_improves_serving_choice(self, loop):
        tel, sim, corrupted, _ = loop
        drv = registry.get("matmul_b16")
        assert drv is not None
        assert drv.source != corrupted.driver.source     # actually swapped
        cfg = choose_or_default("matmul_b16", D_SMALL, MM_DEFAULT)
        assert cfg != MM_DEFAULT
        spec = matmul_spec()
        one = CandidateTable.from_rows(spec.program_params, [cfg])
        t = float(sim.true_time_batch(spec.traffic_table(D_SMALL, one))[0])
        from repro.core import exhaustive_search
        _, best_t, _, _ = exhaustive_search(spec, sim, D_SMALL)
        assert best_t / t >= 0.90     # small-size recovery bar

    def test_fresh_registry_picks_up_versioned_entry(self, loop):
        tel, sim, corrupted, _ = loop
        cache = DriverCache()
        assert cache.latest_version("matmul_b16", V5E.name) == 1
        # invalidate-on-refit: the generation-0 (corrupted) artifact is gone
        entry = cache.lookup_latest("matmul_b16", V5E.name)
        assert entry.tuning_version == 1
        assert entry.source != corrupted.driver.source
        registry.clear()                      # "second process"
        assert warm_start_from_cache() == ["matmul_b16"]
        assert registry.get("matmul_b16").source == entry.source

    def test_counters_and_exporter_consistent(self, loop):
        tel, *_ = loop
        snap = tel.snapshot()
        c = snap["counters"]
        assert c["drift_events_total"] == 1
        assert c["refits_total"] == 1
        assert c["shadow_probes_total"] >= tel.config.min_samples
        assert c["probe_device_seconds_total"] > 0
        assert c["refit_device_seconds_total"] == pytest.approx(
            tel.refits[0].total_device_seconds)
        assert sum(c["choices_by_source"].values()) == c["choices_total"]
        assert snap["refits"][0]["succeeded"] is True


class TestFailedRefit:
    def test_failed_refit_keeps_old_driver_and_pins_override(self, clean,
                                                             monkeypatch):
        """A re-fit that errors must not evict the (drifted but working)
        driver; the searched config still lands as a per-shape override."""
        corrupted = _corrupted_build()
        sim = V5eSimulator(noise=0.04, seed=11)
        tel = Telemetry([matmul_spec()], sim, seed=3, config=TelemetryConfig(
            probe_every=1, min_samples=2,
            refit_budget=SearchBudget(max_executions=64)))

        def broken_build(*a, **k):
            raise RuntimeError("collect blew up")

        monkeypatch.setattr(tel.klaraptor, "build_driver", broken_build)
        with tel:
            for _ in range(8):
                choose_or_default("matmul_b16", D_SMALL, MM_DEFAULT)
                if tel.refits:
                    break
        r = tel.refits[0]
        assert not r.succeeded and "fit:" in r.error
        drv = registry.get("matmul_b16")
        assert drv is not None
        assert drv.source == corrupted.driver.source     # old fit kept
        assert r.override == r.searched_config is not None
        assert registry.override("matmul_b16", V5E.name, D_SMALL) \
            == r.override
        assert choose_or_default("matmul_b16", D_SMALL, MM_DEFAULT) \
            == r.override


class TestExporter:
    def test_snapshot_deterministic_and_json_stable(self, clean):
        sim = V5eSimulator(noise=0.04, seed=1)
        tel = Telemetry([matmul_spec()], sim, cache=False)
        with tel:
            for _ in range(3):
                choose_or_default("nosuchkernel", D_SMALL, MM_DEFAULT)
        assert tel.snapshot() == tel.snapshot()
        assert tel.exporter.json() == tel.exporter.json()
        c = tel.snapshot()["counters"]
        assert c["choices_total"] == 3
        assert c["fallback_default_total"] == 3

    def test_prometheus_format(self, clean):
        sim = V5eSimulator(noise=0.04, seed=1)
        tel = Telemetry([matmul_spec()], sim, cache=False)
        with tel:
            choose_or_default("nosuchkernel", D_SMALL, MM_DEFAULT)
        text = tel.prometheus()
        assert text == tel.prometheus()                 # deterministic
        assert 'klaraptor_choices_total{source="default"} 1' in text
        assert "# TYPE klaraptor_drift_events_total counter" in text
        assert text.endswith("\n")

    def test_listener_errors_never_break_serving(self, clean, monkeypatch):
        import repro.core.driver as driver_mod
        monkeypatch.setattr(driver_mod, "_listener_error_warned", False)

        def bomb(event):
            raise RuntimeError("telemetry bug")

        set_choice_listener(bomb)
        assert choose_or_default("matmul_b16", D_SMALL, MM_DEFAULT) \
            == MM_DEFAULT
        assert get_choice_listener() is bomb


class TestOverridePath:
    def test_override_outranks_driver(self, clean):
        build = _corrupted_build()
        pinned = {"bm": 256, "bn": 256, "bk": 256}
        registry.note_override("matmul_b16", V5E.name, D_SMALL, pinned)
        assert choose_or_default("matmul_b16", D_SMALL, MM_DEFAULT) == pinned
        other = {"m": 2048, "n": 2048, "k": 2048}
        assert choose_or_default("matmul_b16", other, MM_DEFAULT) == \
            build.driver.choose(other)      # only the pinned shape differs

    def test_invalidate_kernel_clears_override(self, clean):
        _corrupted_build()
        registry.note_override("matmul_b16", V5E.name, D_SMALL,
                               {"bm": 256, "bn": 256, "bk": 256})
        registry.invalidate_kernel("matmul_b16")
        assert registry.override("matmul_b16", V5E.name, D_SMALL) is None
        assert registry.get("matmul_b16") is None
