"""repro.obs tests: windowed-series rotation edge cases (empty windows,
clock steps, shard merges), concurrent exporter reads against per-thread
histogram shard writes, label-key round-trips with comma-valued buckets,
the MetricsBus event routing + session-anchor alignment, bit-identical
single-ledger replay, cross-process merge ordering, burn-rate SLO
transitions acting on the ledger and the retune queue, the scorecard's
accuracy rows, and the Observatory install/uninstall contract."""

import json
import threading

import pytest

from repro.fleet import RetuneQueue
from repro.obs import (GaugeRule, MetricsBus, Observatory, RatioRule,
                       SLOEngine, WindowedCounter, WindowedGauge,
                       WindowedHistogram, default_rules, get_metrics_bus,
                       replay_into, replay_ledgers, set_metrics_bus)
from repro.obs.series import label_str, parse_label_str
from repro.trace import Ledger, merge_ledgers

W = 10 ** 9          # 1 s windows everywhere below
T0 = 1_000_000 * W   # an arbitrary wall epoch, far from zero


@pytest.fixture(autouse=True)
def no_ambient_bus():
    set_metrics_bus(None)
    yield
    set_metrics_bus(None)


# ---------------------------------------------------------------------------
# windowed series primitives


def test_counter_rotation_and_rate():
    c = WindowedCounter(W, n_windows=5)
    for i in range(8):
        c.add(T0 + i * W, 2.0)
    assert c.total == 16.0
    # only the newest 5 windows are retained
    assert sorted(c.windows) == [T0 // W + i for i in range(3, 8)]
    now = T0 + 8 * W - 1          # end of the last window
    assert c.sum_over(now, 3 * W) == 6.0
    assert c.rate(now, 3 * W) == pytest.approx(2.0)


def test_empty_window_queries():
    now = T0
    c = WindowedCounter(W, 5)
    g = WindowedGauge(W, 5)
    h = WindowedHistogram(W, 5)
    assert c.sum_over(now, 3 * W) == 0.0
    assert c.rate(now, 3 * W) == 0.0
    assert g.last_over(now, 3 * W) is None
    assert h.quantile(0.5) is None
    assert h.quantile_over(now, 3 * W, 0.5) is None
    # a populated series still answers None/0 over a span it has no data in
    c.add(T0, 1.0)
    g.set(T0, 4.0)
    h.add(T0, 1e-3)
    later = T0 + 100 * W
    assert c.sum_over(later, 3 * W) == 0.0
    assert g.last_over(later, 3 * W) is None
    assert h.quantile_over(later, 3 * W, 0.5) is None


def test_clock_step_backward_lands_in_retained_window():
    c = WindowedCounter(W, n_windows=10)
    c.add(T0 + 5 * W)
    c.add(T0)            # clock stepped back 5 s: older retained window
    assert c.total == 2.0
    assert c.windows[T0 // W] == 1.0
    assert c.sum_over(T0 + 5 * W, 6 * W) == 2.0


def test_clock_step_forward_retires_history():
    c = WindowedCounter(W, n_windows=4)
    for i in range(4):
        c.add(T0 + i * W)
    c.add(T0 + 1000 * W)  # big forward step: all old windows out of horizon
    assert c.total == 5.0
    assert list(c.windows) == [T0 // W + 1000]


def test_gauge_ewma_and_window_last():
    g = WindowedGauge(W, 10, alpha=0.5)
    g.set(T0, 1.0)
    g.set(T0, 3.0)            # same window: last wins for the sparkline
    g.set(T0 + W, 5.0)
    assert g.last == 5.0
    assert g.ewma == pytest.approx(0.5 * 5 + 0.5 * (0.5 * 3 + 0.5 * 1))
    assert g.last_over(T0 + W, 2 * W) == 5.0
    assert g.last_over(T0, W) == 3.0


def test_histogram_quantiles_deterministic():
    h = WindowedHistogram(W, 10)
    for v in (2e-4, 3e-4, 5e-4, 2e-3):
        h.add(T0, v)
    # three samples in the (1e-4, 1e-3] bucket, one in (1e-3, 1e-2]
    assert h.count == 4
    p50 = h.quantile(0.50)
    assert 1e-4 < p50 <= 1e-3
    # twice the same data -> exactly the same quantile (pure arithmetic)
    h2 = WindowedHistogram(W, 10)
    for v in (2e-4, 3e-4, 5e-4, 2e-3):
        h2.add(T0, v)
    assert h2.quantile(0.50) == p50
    assert h.quantile_over(T0, W, 0.50) == p50


def test_histogram_merge_disjoint_windows_and_bounds_mismatch():
    a = WindowedHistogram(W, 100)
    b = WindowedHistogram(W, 100)
    a.add(T0, 1e-4)
    b.add(T0 + 50 * W, 1e-2)      # disjoint window indices
    a.merge(b)
    assert a.count == 2
    assert sorted(a.windows) == [T0 // W, T0 // W + 50]
    # span covering both sees both; span covering one sees one
    assert a.quantile_over(T0 + 50 * W, 60 * W, 0.99) > 1e-3
    assert a.quantile_over(T0, W, 0.99) <= 1e-3
    # overlapping windows add elementwise
    c = WindowedHistogram(W, 100)
    c.add(T0, 1e-4)
    a.merge(c)
    assert a.windows[T0 // W][a._bucket_of(1e-4)] == 2
    with pytest.raises(ValueError):
        a.merge(WindowedHistogram(W, 100, bounds_s=(1.0, 2.0)))


def test_concurrent_shard_writes_vs_exporter_merges():
    """Per-thread histogram shards stay mergeable while their owners are
    mid-write: the exporter's merged reads must never raise and the final
    merge must account for every sample."""
    n_threads, n_each = 4, 3000
    shards = [WindowedHistogram(W, 600) for _ in range(n_threads)]
    stop = threading.Event()
    errors = []

    def writer(shard, seed):
        for i in range(n_each):
            shard.add(T0 + (i % 120) * W, (1 + seed) * 1e-5)

    def reader():
        while not stop.is_set():
            try:
                merged = WindowedHistogram(W, 600)
                for s in shards:
                    merged.merge(s)
                merged.quantile(0.95)
                merged.quantile_over(T0 + 119 * W, 60 * W, 0.5)
            except Exception as e:     # pragma: no cover - the failure mode
                errors.append(e)
                return

    threads = [threading.Thread(target=writer, args=(s, i))
               for i, s in enumerate(shards)]
    r = threading.Thread(target=reader)
    r.start()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    stop.set()
    r.join()
    assert errors == []
    final = WindowedHistogram(W, 600)
    for s in shards:
        final.merge(s)
    assert final.count == n_threads * n_each


def test_concurrent_bus_ingest_vs_snapshot_reads():
    bus = MetricsBus(window_s=1.0, n_windows=600)
    stop = threading.Event()
    errors = []

    def writer(k):
        for i in range(2000):
            bus.ingest({"type": "choice", "kernel": f"k{k}",
                        "source": "plan", "wall_ns": T0 + (i % 60) * W})

    def reader():
        while not stop.is_set():
            try:
                bus.snapshot()
                bus.prometheus()
            except Exception as e:     # pragma: no cover
                errors.append(e)
                return

    threads = [threading.Thread(target=writer, args=(k,)) for k in range(4)]
    r = threading.Thread(target=reader)
    r.start()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    stop.set()
    r.join()
    assert errors == []
    assert bus.counter("choices", {"source": "plan"}).total == 8000


# ---------------------------------------------------------------------------
# label keys


def test_label_round_trip_with_comma_valued_bucket():
    labels = {"kernel": "flash", "hw": "v5e", "bucket": "bh5,skv7,sq7"}
    assert parse_label_str(label_str(labels)) == labels
    assert parse_label_str("") == {}


def test_sum_counters_matches_comma_valued_label():
    bus = MetricsBus()
    bus.counter("x", {"bucket": "a,b,c", "kernel": "mm"}).add(T0, 3.0)
    bus.counter("x", {"bucket": "d", "kernel": "mm"}).add(T0, 1.0)
    assert bus.sum_counters("x", T0, W, bucket="a,b,c") == 3.0
    assert bus.sum_counters("x", T0, W, kernel="mm") == 4.0
    assert bus.sum_counters("x", T0, W, kernel="nope") == 0.0


# ---------------------------------------------------------------------------
# bus routing + anchors


def test_bus_routes_and_anchor_alignment():
    bus = MetricsBus()
    bus.ingest({"type": "session", "pid": 1, "wall_ns": T0, "mono_ns": 0})
    # t_ns is monotonic; the anchor maps it to wall time
    bus.ingest({"type": "choice", "kernel": "mm", "source": "plan",
                "n_coalesced": 4, "t_ns": 3 * W})
    assert bus.last_wall_ns == T0 + 3 * W
    assert bus.counter("choices", {"source": "plan"}).total == 4.0
    assert bus.counter("launches", {"kernel": "mm"}).total == 4.0
    assert bus.counter("fallback").total == 0.0
    # an explicit wall_ns beats the anchor (merged cross-process streams)
    bus.ingest({"type": "choice", "kernel": "mm", "source": "default",
                "t_ns": 5 * W, "wall_ns": T0 + 9 * W})
    assert bus.last_wall_ns == T0 + 9 * W
    assert bus.counter("fallback").total == 1.0
    # round trip: wall -> mono lands alerts back on the same wall time
    assert bus.wall_ns_of({"t_ns": bus.mono_ns_of_wall(T0 + 7 * W)}) \
        == T0 + 7 * W


def test_bus_routes_every_event_type():
    bus = MetricsBus()
    t = {"wall_ns": T0}
    bus.ingest({"type": "probe", "kernel": "mm", "hw": "v5e", "bucket": "b",
                "rel_error_ewma": 0.12, **t})
    bus.ingest({"type": "drift", "kernel": "mm", **t})
    bus.ingest({"type": "refit", "succeeded": True, "wall_seconds": 2.0,
                "total_device_seconds": 0.5, **t})
    bus.ingest({"type": "alert", "slo": "s", "state": "breach", **t})
    bus.ingest({"type": "bucket_step", "hit": False, "waste": 0.4,
                "kernel": "mm", **t})
    bus.ingest({"type": "span", "name": "decode", "dur_s": 1e-3, **t})
    snap = bus.snapshot()
    assert snap["n_events"] == 6
    assert bus.counter("probes", {"kernel": "mm"}).total == 1.0
    assert bus.gauge("rel_error_ewma", {"kernel": "mm", "hw": "v5e",
                                        "bucket": "b"}).last == 0.12
    assert bus.counter("drift_events", {"kernel": "mm"}).total == 1.0
    assert bus.counter("refits", {"outcome": "ok"}).total == 1.0
    assert bus.histogram("refit_wall_s").count == 1
    assert bus.counter("alerts", {"slo": "s", "state": "breach"}).total == 1.0
    assert bus.counter("bucket_steps", {"kernel": "mm",
                                        "outcome": "miss"}).total == 1.0
    assert bus.counter("padding_waste_sum",
                       {"kernel": "mm"}).total == pytest.approx(0.4)
    assert bus.histogram("span_duration_s", {"name": "decode"}).count == 1


def test_prometheus_exposition_shape():
    bus = MetricsBus()
    bus.ingest({"type": "choice", "kernel": 'm"m', "source": "plan",
                "wall_ns": T0})
    bus.ingest({"type": "span", "name": "step", "dur_s": 5e-4,
                "wall_ns": T0})
    text = bus.prometheus()
    assert '# TYPE klaraptor_obs_choices_total counter' in text
    assert 'kernel="m\\"m"' in text            # label escaping
    assert 'le="+Inf"' in text
    assert text.count("span_duration_s_bucket") == 9


# ---------------------------------------------------------------------------
# replay: bit identity + cross-process merge ordering


def _emit_demo_run(tmp_path, name="run.jsonl", queue=None):
    led = Ledger(tmp_path / name)
    obs = Observatory(ledger=led, queue=queue)
    for i in range(40):
        t = i * W
        ev = {"type": "choice", "kernel": "mm", "hw": "tpu_v5e",
              "D": {"m": 512, "n": 512, "k": 512},
              "config": {"bm": 128, "bn": 128, "bk": 128},
              "source": "plan" if i % 4 else "default",
              "predicted_s": 1e-4, "n_coalesced": 2, "t_ns": t}
        led.append(ev)
        obs.bus.ingest(ev)
        if i % 5 == 0:
            ev = {"type": "probe", "kernel": "mm", "hw": "tpu_v5e",
                  "bucket": "m9,n9,k9", "predicted_s": 1e-4,
                  "observed_s": 1e-4 * (2.5 if i >= 20 else 1.05),
                  "rel_error_ewma": 1.5 if i >= 20 else 0.05, "t_ns": t}
            led.append(ev)
            obs.bus.ingest(ev)
    obs.evaluate()
    led.close()
    return obs


def test_single_ledger_replay_is_bit_identical(tmp_path):
    live = _emit_demo_run(tmp_path)
    replayed = replay_ledgers(tmp_path / "run.jsonl")
    assert live.bus.snapshot_json() == replayed.bus.snapshot_json()
    # the SLO evaluation over the replayed series reaches the same state
    assert json.dumps(live.snapshot()["scorecard"], sort_keys=True) == \
        json.dumps(replayed.snapshot()["scorecard"], sort_keys=True)


def test_cross_process_merge_ordering(tmp_path):
    """Two ledgers from 'processes' whose monotonic clocks share nothing:
    merged replay must order events by per-process anchored wall time."""
    a, b = tmp_path / "a.jsonl", tmp_path / "b.jsonl"
    with open(a, "w") as f:
        f.write(json.dumps({"type": "session", "pid": 1,
                            "wall_ns": T0, "mono_ns": 7_000 * W}) + "\n")
        for i in (0, 2, 4):
            f.write(json.dumps({"type": "choice", "kernel": f"a{i}",
                                "source": "plan",
                                "t_ns": 7_000 * W + i * W}) + "\n")
    with open(b, "w") as f:
        f.write(json.dumps({"type": "session", "pid": 2,
                            "wall_ns": T0 + W, "mono_ns": 3 * W}) + "\n")
        for i in (0, 2):
            f.write(json.dumps({"type": "choice", "kernel": f"b{i}",
                                "source": "plan",
                                "t_ns": 3 * W + i * W}) + "\n")
    merged = [e for e in merge_ledgers([a, b]) if e["type"] == "choice"]
    # wall times: a0@T0, b0@T0+1, a2@T0+2, b2@T0+3, a4@T0+4
    assert [e["kernel"] for e in merged] == ["a0", "b0", "a2", "b2", "a4"]
    assert [e["wall_ns"] for e in merged] == [T0 + i * W for i in range(5)]
    # replaying the merged stream lands each event in its own wall window
    bus = MetricsBus()
    replay_into(bus, [a, b])
    c = bus.counter("choices", {"source": "plan"})
    assert {i - T0 // W for i in c.windows} == {0, 1, 2, 3, 4}


def test_replay_strict_flag_propagates(tmp_path):
    p = tmp_path / "bad.jsonl"
    with open(p, "w") as f:
        f.write('{"type": "choice", "source": "plan"}\n')
        f.write("{torn")
        f.write("\n")
        f.write('{"type": "choice", "source": "plan"}\n')
    bus = MetricsBus()
    assert replay_into(bus, p) == 2            # lenient: skip, keep going
    with pytest.raises(ValueError):
        replay_into(MetricsBus(), p, strict=True)


# ---------------------------------------------------------------------------
# SLO engine


def _burnable_bus(frac_default=0.2, n=50):
    bus = MetricsBus()
    bus.ingest({"type": "session", "pid": 1, "wall_ns": T0, "mono_ns": 0})
    n_def = int(n * frac_default)
    for i in range(n):
        bus.ingest({"type": "choice", "kernel": "mm", "source":
                    "default" if i < n_def else "plan", "t_ns": i * W // 4})
    return bus


def test_slo_breach_fires_once_and_resolves():
    engine = SLOEngine(rules=[RatioRule(
        name="fallback_rate", objective=0.02,
        num=("choices", {"source": "default"}), den=("choices", {}))])
    bus = _burnable_bus(frac_default=0.2)
    alerts = engine.evaluate(bus)
    assert [a.state for a in alerts] == ["breach"]
    assert alerts[0].burn_fast >= 2.0 and alerts[0].burn_slow >= 1.0
    # sustained breach: no new transition on the next tick
    assert engine.evaluate(bus) == []
    assert ("fallback_rate", "") in engine.firing
    # the bad window ages out of both windows -> resolve transition
    later = bus.last_wall_ns + 1_000 * W
    bus.ingest({"type": "choice", "kernel": "mm", "source": "plan",
                "wall_ns": later})
    resolved = engine.evaluate(bus, now_ns=later)
    assert [a.state for a in resolved] == ["resolve"]
    assert engine.firing == {}


def test_slo_fast_window_gate_blocks_stale_breach():
    """Burn in the slow window only (the incident is over) must not page."""
    engine = SLOEngine(rules=[RatioRule(
        name="fallback_rate", objective=0.02,
        num=("choices", {"source": "default"}), den=("choices", {}))])
    bus = MetricsBus()
    bus.ingest({"type": "session", "pid": 1, "wall_ns": T0, "mono_ns": 0})
    for i in range(20):    # all defaults, but 100+ seconds ago
        bus.ingest({"type": "choice", "source": "default",
                    "wall_ns": T0 + i * W})
    now = T0 + 200 * W
    for i in range(20):    # recent traffic is clean
        bus.ingest({"type": "choice", "source": "plan",
                    "wall_ns": now - 20 * W + i * W})
    assert engine.evaluate(bus, now_ns=now) == []


def test_slo_alert_lands_in_ledger_and_enqueues_retune(tmp_path):
    led = Ledger(tmp_path / "slo.jsonl")
    q = RetuneQueue(tmp_path / "q.json")
    rule = GaugeRule(name="drift_ewma", objective=0.25,
                     gauge="rel_error_ewma", retune=True, retune_boost=1e3)
    engine = SLOEngine(rules=[rule], ledger=led, queue=q,
                       enrich=lambda key: {"D": {"m": 64}})
    bus = MetricsBus()
    bus.ingest({"type": "session", "pid": 1, "wall_ns": T0, "mono_ns": 0})
    bus.ingest({"type": "probe", "kernel": "mm", "hw": "v5e",
                "bucket": "b1,b2", "rel_error_ewma": 2.0, "t_ns": 0})
    alerts = engine.evaluate(bus)
    led.close()
    assert len(alerts) == 1 and alerts[0].state == "breach"
    # the alert line is in the ledger AND was ingested back into the bus
    from repro.trace import read_ledger
    events = read_ledger(tmp_path / "slo.jsonl")
    ledger_alerts = [e for e in events if e["type"] == "alert"]
    assert len(ledger_alerts) == 1
    assert ledger_alerts[0]["key"] == {"kernel": "mm", "hw": "v5e",
                                       "bucket": "b1,b2"}
    assert bus.counter("alerts", {"slo": "drift_ewma",
                                  "state": "breach"}).total == 1.0
    # the breached key is pending in the retune queue, boosted and enriched
    pend = q.pending()
    assert len(pend) == 1
    key, ev = pend[0]
    assert key == "mm|v5e|b1,b2"
    assert ev["slo"] == "drift_ewma" and ev["D"] == {"m": 64}
    assert q.state["pending"][key]["boost"] == 1e3


def test_default_rules_cover_the_documented_invariants():
    names = {r.name for r in default_rules()}
    assert names == {"fallback_rate", "bucket_miss_rate", "padding_waste",
                     "drift_ewma", "refit_latency"}
    waste = next(r for r in default_rules() if r.name == "padding_waste")
    assert waste.retune and waste.group_by == ("kernel",)


# ---------------------------------------------------------------------------
# scorecard


def test_scorecard_ratio_refit_and_enrich():
    bus = MetricsBus()
    obs = Observatory()
    obs.bus = bus     # not installed; just wiring the subscriber
    card = obs.scorecard
    card.attach(bus)
    t = {"wall_ns": T0}
    bus.ingest({"type": "choice", "kernel": "mm", "hw": "v5e",
                "D": {"m": 512, "n": 512, "k": 512},
                "config": {"bm": 128}, "n_coalesced": 3, **t})
    for obs_s in (1.1e-4, 1.2e-4, 3.0e-4):
        bus.ingest({"type": "probe", "kernel": "mm", "hw": "v5e",
                    "bucket": "k9,m9,n9", "predicted_s": 1e-4,
                    "observed_s": obs_s, "rel_error_ewma": 0.3, **t})
    row = card.rows["mm|v5e|k9,m9,n9"]
    assert row.probes == 3
    cal = row.calibration()
    assert cal["p50"] == pytest.approx(1.2)
    assert card.within_slo(row) is True
    # enrichment resolves a coarse (kernel-only) key to the busiest row
    extra = card.enrich({"kernel": "mm"})
    assert extra["hw"] == "v5e" and extra["bucket"] == "k9,m9,n9"
    assert extra["observed_s"] == pytest.approx(3.0e-4)
    # a successful refit wipes the ring and stamps the version
    bus.ingest({"type": "refit", "kernel": "mm", "succeeded": True,
                "cache_version": 7, **t})
    assert len(row.ratios) == 0 and row.tuning_version == 7
    assert card.within_slo(row) is None
    # corpus rows carry the full labeled example
    rows = card.corpus_rows()
    assert len(rows) == 3
    assert rows[0]["config"] == {"bm": 128}
    text = card.render_text()
    assert "mm" in text and "ratio p50" in text


def test_scorecard_corpus_write(tmp_path):
    bus = MetricsBus()
    from repro.obs import Scorecard
    card = Scorecard().attach(bus)
    bus.ingest({"type": "probe", "kernel": "mm", "hw": "v5e", "bucket": "b",
                "predicted_s": 1e-4, "observed_s": 2e-4, "wall_ns": T0})
    p = tmp_path / "corpus.jsonl"
    assert card.write_corpus(p) == 1
    row = json.loads(p.read_text().strip())
    assert row["observed_s"] == 2e-4 and row["tuning_version"] is None


# ---------------------------------------------------------------------------
# observatory lifecycle


def test_observatory_install_uninstall_and_zero_cost_default():
    assert get_metrics_bus() is None
    obs = Observatory()
    with obs:
        assert get_metrics_bus() is obs.bus
    assert get_metrics_bus() is None
    # installing a second observatory then uninstalling the first must not
    # tear down the second's bus
    o1, o2 = Observatory(), Observatory()
    o1.install()
    o2.install()
    o1.uninstall()
    assert get_metrics_bus() is o2.bus
    o2.uninstall()


def test_observatory_counts_session_header_like_replay(tmp_path):
    led = Ledger(tmp_path / "x.jsonl")
    obs = Observatory(ledger=led)
    led.close()
    # live bus saw exactly the one event replay will read back
    assert obs.bus.n_events == 1
    replayed = replay_ledgers(tmp_path / "x.jsonl")
    assert obs.bus.snapshot_json() == replayed.bus.snapshot_json()


def test_telemetry_note_bucket_step_reaches_bus_without_ledger():
    from repro.core import V5E, V5eSimulator, matmul_spec
    from repro.telemetry import Telemetry
    tel = Telemetry([matmul_spec()], V5eSimulator(V5E), cache=False)
    obs = Observatory()
    with obs:
        tel.note_bucket_step(True, 0.25, kernel="mm")
    tel.note_bucket_step(True, 0.25, kernel="mm")   # bus gone: no ingest
    assert obs.bus.counter("bucket_steps",
                           {"kernel": "mm", "outcome": "hit"}).total == 1.0
    snap = tel.exporter.snapshot()
    assert snap["counters"]["bucket_hits"] == 2
