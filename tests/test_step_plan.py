"""Zero-host-overhead dispatch: device plan tables, per-step launch plans,
and the choose_or_default decision memo.

Load-bearing properties:
  * ``DevicePlanTable`` lookups are bit-identical to the host
    ``LaunchPlanTable`` on every tier-1 kernel -- hits, misses, and hash
    collisions (the 32-bit device hash collides more readily than the
    64-bit host hash; dims verification must make that invisible).
  * A frozen ``StepPlan`` never serves across a registry generation bump
    (refit hot-swap, pinned override, new plan) -- and the fall-through
    ordering makes "pinned override > step plan > registry" hold.
  * The decision memo serves bit-identical repeats, dies with the
    generation, and keeps telemetry honest (full-fidelity window, then
    coalesced events whose n_coalesced preserves launch counts).
"""

import numpy as np
import pytest

from repro.core import (DriverProgram, Klaraptor, V5E, V5eSimulator,
                        choose_or_default, compile_plan, dkey,
                        flash_attention_spec, lattice, matmul_spec,
                        memo_key, moe_gmm_spec, registry,
                        set_choice_listener, set_decision_memo,
                        ssd_scan_spec)
from repro.core.device_plan import DevicePlanTable, pack_shape32
from repro.core.plan import LaunchPlanTable
from repro.core.step_plan import (KernelRequest, StepPlan, active_step_plan,
                                  build_step_plan, use_step_plan)

SPECS = {
    "matmul": matmul_spec,
    "flash": flash_attention_spec,
    "moe": moe_gmm_spec,
    "ssd": ssd_scan_spec,
}

ENVELOPES = {
    "matmul": {"m": [512, 1024, 2048, 4096], "n": [512, 1024, 2048, 4096],
               "k": [512, 1024]},
    "flash": {"bh": [2, 8], "sq": [512, 1024, 2048, 4096],
              "skv": [1024, 2048]},
    "moe": {"e": [2, 8], "g": [256, 1024], "k": [512, 1024],
            "n": [512, 1024]},
    "ssd": {"bh": [2, 8], "s": [1024, 2048, 4096], "chunkflops": [1]},
}


@pytest.fixture(scope="module")
def builds():
    """One driver per tier-1 spec, built once (registry untouched)."""
    sim = V5eSimulator(noise=0.03, seed=7)
    kl = Klaraptor(sim, cache=False)
    return {name: kl.build_driver(fn(), repeats=2, max_configs_per_size=16,
                                  register=False)
            for name, fn in SPECS.items()}


@pytest.fixture()
def clean(tmp_path, monkeypatch):
    monkeypatch.setenv("KLARAPTOR_CACHE_DIR", str(tmp_path / "cache"))
    registry.clear()
    set_choice_listener(None)
    yield
    registry.clear()
    set_choice_listener(None)


def _rows(driver, cols):
    n = next(iter(cols.values())).shape[0]
    return [{d: int(cols[d][i]) for d in driver.data_params}
            for i in range(n)]


# ---------------------------------------------------------------------------
# DevicePlanTable: bit-identity with the host table on all tier-1 kernels
# ---------------------------------------------------------------------------

class TestDevicePlanTable:
    @pytest.mark.parametrize("name", sorted(SPECS))
    def test_bit_identical_hits_and_misses(self, builds, name):
        driver = builds[name].driver
        cols = lattice(ENVELOPES[name])
        table = compile_plan(driver, cols)
        dev = table.to_device()
        assert len(dev) == len(table)
        # every envelope point: identical config dict (hit for hit)
        for D in _rows(driver, cols):
            assert dev.lookup_dims(D) == table.lookup(D), (name, D)
        # misses: perturbed shapes, missing data params, extra keys ignored
        some = _rows(driver, cols)[0]
        off = {d: v + 1 for d, v in some.items()}
        assert dev.lookup_dims(off) == table.lookup(off)
        partial = dict(list(some.items())[:-1])
        assert dev.lookup_dims(partial) is None \
            and table.lookup(partial) is None
        extra = {**some, "zzz": 1}
        assert dev.lookup_dims(extra) == table.lookup(extra)

    def test_in_graph_lookup(self, builds):
        """The probe is jit-traceable: callable from inside a compiled step
        with array inputs, matching the host lookup's row."""
        import jax
        import jax.numpy as jnp

        driver = builds["matmul"].driver
        table = compile_plan(driver, lattice(ENVELOPES["matmul"]))
        dev = table.to_device()

        @jax.jit
        def step(keys):
            row, found = dev.lookup(keys)
            return row, found

        D = {"m": 1024, "n": 2048, "k": 512}
        row, found = step(jnp.array([1024, 2048, 512], dtype=jnp.int32))
        want = table.lookup(D)
        assert bool(found)
        assert {p: int(np.asarray(row)[i])
                for i, p in enumerate(dev.program_params)} == want
        _, found = step(jnp.array([999, 2048, 512], dtype=jnp.int32))
        assert not bool(found)

    def test_vmapped_batched_lookup(self, builds):
        """``lookup`` composes with ``jax.vmap``: one batched probe over a
        mixed batch of hits and misses resolves row-per-key, bit-identical
        to the host table -- the shape a batched serving step would use."""
        import jax
        import jax.numpy as jnp

        driver = builds["matmul"].driver
        cols = lattice(ENVELOPES["matmul"])
        table = compile_plan(driver, cols)
        dev = table.to_device()

        batch_D = _rows(driver, cols)[:6] + [
            {"m": 999, "n": 2048, "k": 512},      # miss: unplanned shape
            {"m": 0, "n": 0, "k": 0},             # miss: degenerate key
        ]
        keys = jnp.asarray([[D[d] for d in driver.data_params]
                            for D in batch_D], dtype=jnp.int32)
        batched = jax.jit(jax.vmap(dev.lookup))
        rows, found = batched(keys)
        assert rows.shape == (len(batch_D), len(dev.program_params))
        assert found.shape == (len(batch_D),)
        for i, D in enumerate(batch_D):
            want = table.lookup(D)
            if want is None:
                assert not bool(found[i]), D
            else:
                assert bool(found[i]), D
                assert {p: int(np.asarray(rows)[i][j])
                        for j, p in enumerate(dev.program_params)} == want

    def test_in_jit_miss_takes_default_branch(self, builds):
        """A lookup miss inside a compiled step selects the fallback
        branch (no retrace, no host round-trip): the step stays at one
        trace across hits and misses."""
        import jax
        import jax.numpy as jnp

        driver = builds["matmul"].driver
        table = compile_plan(driver, lattice(ENVELOPES["matmul"]))
        dev = table.to_device()
        traces = {"n": 0}
        sentinel = jnp.full((len(dev.program_params),), -7, jnp.int32)

        @jax.jit
        def step(keys):
            traces["n"] += 1
            row, found = dev.lookup(keys)
            return jnp.where(found, row, sentinel), found

        hit_D = {"m": 1024, "n": 2048, "k": 512}
        row, found = step(jnp.asarray([1024, 2048, 512], jnp.int32))
        assert bool(found)
        assert {p: int(np.asarray(row)[i])
                for i, p in enumerate(dev.program_params)} == \
            table.lookup(hit_D)
        row, found = step(jnp.asarray([1024, 2048, 513], jnp.int32))
        assert not bool(found)
        assert np.asarray(row).tolist() == [-7] * len(dev.program_params)
        assert traces["n"] == 1

    def test_slot_collisions_resolved(self):
        """Keys whose home slots collide (forced linear-probe chain) all
        resolve to their own configs, on host and device."""
        # find 6 single-dim keys sharing one home slot at capacity 16
        cap, target, keys = 16, None, []
        v = 1
        while len(keys) < 6:
            slot = pack_shape32((v,)) & (cap - 1)
            if target is None:
                target = slot
            if slot == target:
                keys.append(v)
            v += 1
        # capacity for 6 entries is 16, so all six chain off one slot
        table = LaunchPlanTable.build(
            "k", V5E.name, ("a",), ("x",),
            {"a": np.array(keys)}, {"x": np.array([10 * k for k in keys])})
        dev = table.to_device()
        assert dev.capacity == cap and dev.max_probe >= len(keys)
        for k in keys:
            assert dev.lookup_dims({"a": k}) == {"x": 10 * k}
            assert dev.lookup_dims({"a": k}) == table.lookup({"a": k})
        # a probe that walks the full chain and still misses
        miss = next(v for v in range(v, v + 10 ** 6)
                    if (pack_shape32((v,)) & (cap - 1)) == target
                    and v not in keys)
        assert dev.lookup_dims({"a": miss}) is None

    def test_full_hash_collision_is_safe(self):
        """Two distinct shapes with the same 32-bit packed hash must never
        serve each other's config: dims are verified on every probe.

        Single-element keys can't collide (the fmix32 chain is bijective in
        one value), so the birthday search runs over two-dim shapes.
        """
        seen: dict[int, tuple[int, int]] = {}
        a = b = None
        for v in range(1, 1 << 22):
            key = (v & 0xFFFF, v >> 16)
            h = pack_shape32(key)
            if h in seen and seen[h] != key:
                a, b = seen[h], key
                break
            seen[h] = key
        assert a is not None, "no 32-bit collision found in range"
        assert a != b and pack_shape32(a) == pack_shape32(b)
        table = LaunchPlanTable.build(
            "k", V5E.name, ("p", "q"), ("x",),
            {"p": np.array([a[0]]), "q": np.array([a[1]])},
            {"x": np.array([111])})
        dev = table.to_device()
        assert dev.lookup_dims({"p": a[0], "q": a[1]}) == {"x": 111}
        # hash hit, dims differ: the probe must reject, not serve a's config
        assert dev.lookup_dims({"p": b[0], "q": b[1]}) is None
        # and with both inserted, each gets exactly its own config
        table2 = LaunchPlanTable.build(
            "k", V5E.name, ("p", "q"), ("x",),
            {"p": np.array([a[0], b[0]]), "q": np.array([a[1], b[1]])},
            {"x": np.array([111, 222])})
        dev2 = table2.to_device()
        assert dev2.lookup_dims({"p": a[0], "q": a[1]}) == {"x": 111}
        assert dev2.lookup_dims({"p": b[0], "q": b[1]}) == {"x": 222}

    def test_empty_table(self):
        table = LaunchPlanTable.build("k", V5E.name, ("a",), ("x",),
                                      {"a": np.array([], dtype=np.int64)},
                                      {"x": np.array([], dtype=np.int64)})
        dev = table.to_device()
        assert len(dev) == 0
        assert dev.lookup_dims({"a": 7}) is None


# ---------------------------------------------------------------------------
# StepPlan: batched build, bit-identity, generation invalidation
# ---------------------------------------------------------------------------

class TestStepPlan:
    def _requests(self, driver, cols, default=None):
        return [KernelRequest.make(driver.kernel, D,
                                   default or {"zz": -1})
                for D in _rows(driver, cols)]

    def test_build_matches_choose_bit_identical(self, clean, builds):
        """StepPlan's batched sweep must pick what per-shape choose()
        picks, for every tier-1 kernel in one multi-kernel build."""
        from repro.core import register_driver
        reqs = []
        for name in sorted(SPECS):
            register_driver(builds[name].driver)
        for name in sorted(SPECS):
            driver = builds[name].driver
            reqs += self._requests(driver, lattice(ENVELOPES[name]))
        plan = build_step_plan(reqs)
        assert plan.describe()["sources"] == {"driver": len(plan)}
        for name in sorted(SPECS):
            driver = builds[name].driver
            for D in _rows(driver, lattice(ENVELOPES[name])):
                driver.namespace["_HISTORY"].clear()
                assert plan.resolve(driver.kernel, D) == driver.choose(D), \
                    (name, D)

    def test_default_for_untuned_kernel(self, clean):
        plan = build_step_plan([KernelRequest.make(
            "nonexistent", {"m": 8}, {"bm": 128})])
        assert plan.resolve("nonexistent", {"m": 8}) == {"bm": 128}
        assert plan.describe()["sources"] == {"default": 1}

    def test_override_and_plan_outrank_driver_at_build(self, clean, builds):
        from repro.core import register_driver
        driver = builds["matmul"].driver
        register_driver(driver)
        registry.register_plan(compile_plan(driver,
                                            lattice(ENVELOPES["matmul"])))
        D_pin = {"m": 512, "n": 512, "k": 512}
        pinned = {"bm": 8, "bn": 128, "bk": 128}
        registry.note_override(driver.kernel, V5E.name, D_pin, pinned)
        D_plan = {"m": 1024, "n": 2048, "k": 512}
        D_out = {"m": 96, "n": 384, "k": 640}       # outside the envelope
        plan = build_step_plan([
            KernelRequest.make(driver.kernel, D, {"bm": -1})
            for D in (D_pin, D_plan, D_out)])
        assert plan.resolve(driver.kernel, D_pin) == pinned
        src = plan.sources
        assert src[(driver.kernel, dkey(D_pin))] == "override"
        assert src[(driver.kernel, dkey(D_plan))] == "plan"
        assert src[(driver.kernel, dkey(D_out))] == "driver"

    def test_generation_bump_invalidates(self, clean, builds):
        from repro.core import register_driver
        driver = builds["matmul"].driver
        register_driver(driver)
        D = {"m": 1024, "n": 2048, "k": 512}
        plan = build_step_plan([KernelRequest.make(driver.kernel, D,
                                                   {"bm": -1})])
        assert not plan.stale()
        assert plan.resolve(driver.kernel, D) is not None
        registry.note_override(driver.kernel, V5E.name, D,
                               {"bm": 8, "bn": 128, "bk": 128})
        assert plan.stale()
        assert plan.resolve(driver.kernel, D) is None

    def test_refit_hot_swap_invalidates(self, clean, builds):
        from repro.core import register_driver
        driver = builds["matmul"].driver
        register_driver(driver)
        D = {"m": 1024, "n": 2048, "k": 512}
        plan = build_step_plan([KernelRequest.make(driver.kernel, D,
                                                   {"bm": -1})])
        # the hot-swap path: invalidate + register a refit generation
        registry.invalidate_kernel(driver.kernel)
        assert plan.stale() and plan.resolve(driver.kernel, D) is None
        refit = DriverProgram.from_source(
            driver.kernel, driver.source + "\n# refit\n", driver.hw,
            tuning_version=1)
        register_driver(refit)
        assert plan.resolve(driver.kernel, D) is None
        # a rebuilt plan against the new generation serves again
        plan2 = build_step_plan([KernelRequest.make(driver.kernel, D,
                                                    {"bm": -1})])
        assert plan2.resolve(driver.kernel, D) == refit.choose(D)

    def test_mid_build_mutation_births_stale_plan(self, clean, builds):
        """A generation bump between snapshot and freeze must produce a
        plan that refuses to serve (mirrors memo_store's guard)."""
        from repro.core import register_driver
        driver = builds["matmul"].driver
        register_driver(driver)
        D = {"m": 1024, "n": 2048, "k": 512}
        gen = registry.generation
        plan = build_step_plan([KernelRequest.make(driver.kernel, D,
                                                   {"bm": -1})])
        assert plan.generation == gen
        # simulate the mutation landing right after the snapshot
        stale = StepPlan(hw_name=plan.hw_name, generation=gen - 1,
                         table=plan.table, sources=plan.sources)
        assert stale.resolve(driver.kernel, D) is None


# ---------------------------------------------------------------------------
# Ops-level dispatch: plan context, precedence, no registry traffic
# ---------------------------------------------------------------------------

class TestOpsDispatch:
    def test_context_and_explicit_plan(self, clean):
        import repro.kernels.ops as ops
        D = {"m": 64, "n": 64, "k": 64}
        plan = build_step_plan([KernelRequest.make(
            "matmul_b32", D, {"bm": 8, "bn": 128, "bk": 128})])
        assert active_step_plan() is None
        with use_step_plan(plan):
            assert active_step_plan() is plan
            got = ops._resolve("matmul_b32", D, ops.MATMUL_DEFAULT, None)
            assert got == {"bm": 8, "bn": 128, "bk": 128}
            with use_step_plan(None):      # inner disable
                assert active_step_plan() is None
        assert active_step_plan() is None
        # explicit argument, no ambient context
        got = ops._resolve("matmul_b32", D, ops.MATMUL_DEFAULT, plan)
        assert got == {"bm": 8, "bn": 128, "bk": 128}

    def test_plan_hit_makes_no_registry_traffic(self, clean):
        import repro.kernels.ops as ops
        D = {"m": 64, "n": 64, "k": 64}
        plan = build_step_plan([KernelRequest.make(
            "matmul_b32", D, {"bm": 8, "bn": 128, "bk": 128})])
        events = []
        set_choice_listener(events.append)
        before = registry.stats()
        with use_step_plan(plan):
            ops._resolve("matmul_b32", D, ops.MATMUL_DEFAULT, None)
        assert events == []                      # no ChoiceEvent emitted
        assert registry.stats() == before        # no counters touched

    def test_pinned_override_outranks_step_plan(self, clean):
        """The acceptance ordering: a fresh override beats a frozen plan
        (the bump stales the plan; choose_or_default serves the pin)."""
        import repro.kernels.ops as ops
        D = {"m": 64, "n": 64, "k": 64}
        plan = build_step_plan([KernelRequest.make(
            "matmul_b32", D, {"bm": 256, "bn": 256, "bk": 256})])
        pinned = {"bm": 8, "bn": 128, "bk": 128}
        registry.note_override("matmul_b32", V5E.name, D, pinned)
        with use_step_plan(plan):
            assert ops._resolve("matmul_b32", D,
                                ops.MATMUL_DEFAULT, None) == pinned

    def test_step_plan_outranks_registry_driver(self, clean, builds):
        from repro.core import register_driver
        import repro.kernels.ops as ops
        driver = builds["matmul"].driver     # kernel name "matmul_b16"
        register_driver(driver)
        D = {"m": 1024, "n": 2048, "k": 512}
        marked = {"bm": 8, "bn": 128, "bk": 128}
        plan = StepPlan(hw_name=V5E.name, generation=registry.generation,
                        table={(driver.kernel, dkey(D)): marked},
                        sources={(driver.kernel, dkey(D)): "test"})
        with use_step_plan(plan):
            assert ops._resolve(driver.kernel, D,
                                ops.MATMUL_DEFAULT, None) == marked
        # without the plan, the registered driver decides
        assert ops._resolve(driver.kernel, D,
                            ops.MATMUL_DEFAULT, None) != marked

    def test_pallas_op_runs_under_step_plan(self, clean):
        import jax.numpy as jnp

        import repro.kernels.ops as ops
        D = {"m": 16, "n": 128, "k": 128}
        plan = build_step_plan([KernelRequest.make(
            "matmul_b32", D, {"bm": 8, "bn": 128, "bk": 128})])
        x = jnp.ones((16, 128), jnp.float32)
        y = jnp.ones((128, 128), jnp.float32)
        events = []
        set_choice_listener(events.append)
        with use_step_plan(plan):
            out = ops.matmul(x, y, use_pallas=True, interpret=True)
        assert out.shape == (16, 128)
        np.testing.assert_allclose(np.asarray(out), 128.0)
        assert events == []                 # dispatched from the plan


# ---------------------------------------------------------------------------
# Decision memo: fast-path identity, invalidation, telemetry accounting
# ---------------------------------------------------------------------------

class TestDecisionMemo:
    def test_repeat_is_bit_identical_and_memoized(self, clean, builds):
        from repro.core import register_driver
        driver = builds["matmul"].driver
        register_driver(driver)
        D = {"m": 1024, "n": 2048, "k": 512}
        first = choose_or_default(driver.kernel, D, {"bm": -1})
        ent = registry.memo_get(memo_key(driver.kernel, V5E.name, D))
        assert ent is not None and ent[1] == "driver"
        second = choose_or_default(driver.kernel, D, {"bm": -1})
        third = choose_or_default(driver.kernel, D, {"bm": -1})
        assert second == first
        # memo hits share one read-only dict (the entry's private copy,
        # never the slow path's return value)
        assert second is not first and second is third
        assert registry.memo_hits() == 2

    def test_generation_bump_drops_memo(self, clean, builds):
        from repro.core import register_driver
        driver = builds["matmul"].driver
        register_driver(driver)
        D = {"m": 1024, "n": 2048, "k": 512}
        choose_or_default(driver.kernel, D, {"bm": -1})
        pinned = {"bm": 8, "bn": 128, "bk": 128}
        registry.note_override(driver.kernel, V5E.name, D, pinned)
        assert registry.memo_get(
            memo_key(driver.kernel, V5E.name, D)) is None
        assert choose_or_default(driver.kernel, D, {"bm": -1}) == pinned

    def test_default_path_not_memoized(self, clean):
        cfg = choose_or_default("untuned_kernel", {"m": 8}, {"bm": 64})
        assert cfg == {"bm": 64}
        assert registry.memo_get(
            memo_key("untuned_kernel", V5E.name, {"m": 8})) is None
        # different call sites may pass different defaults; each must win
        assert choose_or_default("untuned_kernel", {"m": 8},
                                 {"bm": 32}) == {"bm": 32}

    def test_no_estimate_without_listener(self, clean, builds):
        """Satellite: an untelemetered launch must not pay estimate()."""
        from repro.core import register_driver
        driver = builds["matmul"].driver
        register_driver(driver)
        D = {"m": 1024, "n": 2048, "k": 512}
        calls = {"n": 0}
        inner = driver.namespace["estimate"]

        def counting(**kw):
            calls["n"] += 1
            return inner(**kw)

        driver.namespace["estimate"] = counting
        try:
            choose_or_default(driver.kernel, D, {"bm": -1})
            baseline = calls["n"]   # choose() itself may estimate
            for _ in range(5):
                choose_or_default(driver.kernel, D, {"bm": -1})
            assert calls["n"] == baseline       # memo hits: zero estimates
            set_choice_listener(lambda e: None)
            choose_or_default(driver.kernel, D, {"bm": -1})
            assert calls["n"] == baseline + 1   # listener: fresh prediction
        finally:
            driver.namespace["estimate"] = inner

    def test_full_window_then_coalesced_events(self, clean, builds):
        from repro.core import register_driver
        from repro.core.driver import MEMO_FULL_WINDOW, MEMO_NOTIFY_EVERY
        driver = builds["matmul"].driver
        register_driver(driver)
        D = {"m": 1024, "n": 2048, "k": 512}
        events = []
        set_choice_listener(events.append)
        total = 1 + MEMO_FULL_WINDOW + MEMO_NOTIFY_EVERY
        for _ in range(total):
            choose_or_default(driver.kernel, D, {"bm": -1})
        # slow path + full-fidelity window + exactly one coalesced event
        assert len(events) == 1 + MEMO_FULL_WINDOW + 1
        window = events[:1 + MEMO_FULL_WINDOW]
        assert all(e.n_coalesced == 1 and e.source == "driver"
                   and e.predicted_s is not None for e in window)
        assert events[-1].n_coalesced == MEMO_NOTIFY_EVERY
        # every launch accounted for exactly once
        assert sum(e.n_coalesced for e in events) == total

    def test_telemetry_counts_coalesced_launches(self, clean, builds):
        from repro.core import register_driver
        from repro.core.driver import MEMO_FULL_WINDOW, MEMO_NOTIFY_EVERY
        from repro.telemetry import Telemetry, TelemetryConfig
        driver = builds["matmul"].driver
        register_driver(driver)
        D = {"m": 1024, "n": 2048, "k": 512}
        # refits disabled: a drift-triggered refit would bump the registry
        # generation mid-loop and (correctly) drop pending coalesced hits,
        # which is not the accounting identity under test here.
        tel = Telemetry([matmul_spec()], V5eSimulator(seed=0), cache=False,
                        config=TelemetryConfig(refit_enabled=False))
        total = 1 + MEMO_FULL_WINDOW + MEMO_NOTIFY_EVERY
        with tel:
            for _ in range(total):
                choose_or_default(driver.kernel, D, {"bm": -1})
        snap = tel.snapshot()
        assert snap["counters"]["choices_total"] == total
        assert snap["counters"]["choices_by_source"] == {"driver": total}
        (key,) = snap["keys"]
        assert key["n_choices"] == total

    def test_disable_enable(self, clean, builds):
        from repro.core import register_driver
        driver = builds["matmul"].driver
        register_driver(driver)
        D = {"m": 1024, "n": 2048, "k": 512}
        prev = set_decision_memo(False)
        try:
            choose_or_default(driver.kernel, D, {"bm": -1})
            assert registry.memo_get(
                memo_key(driver.kernel, V5E.name, D)) is None
        finally:
            set_decision_memo(prev)
        choose_or_default(driver.kernel, D, {"bm": -1})
        assert registry.memo_get(
            memo_key(driver.kernel, V5E.name, D)) is not None


# ---------------------------------------------------------------------------
# Engine integration: the step plan rides the serving loop
# ---------------------------------------------------------------------------

@pytest.mark.slow
class TestEngineStepPlan:
    def test_engine_builds_and_refreshes(self, clean):
        from repro.configs import get_config
        from repro.launch.serve import build_engine
        from repro.serving import Request

        cfg = get_config("llama3.2-1b", smoke=True)
        if not cfg.use_pallas:
            cfg = cfg.replace(use_pallas=True)
        engine = build_engine(cfg, batch=2, max_seq=16)
        plan = engine._step_plan
        assert plan is not None and len(plan) > 0
        assert not plan.stale()
        # a pinned override lands: next step rebuilds against it
        some_kernel, some_D = next(iter(plan.table))
        registry.note_override(some_kernel, V5E.name, dict(some_D),
                               dict(plan.table[(some_kernel, some_D)]))
        assert plan.stale()
        engine.submit(Request(rid=0, prompt=[3, 5], max_new_tokens=2))
        engine.run()
        assert engine._step_plan is not plan
        assert not engine._step_plan.stale()

    def test_engine_without_pallas_skips_plan(self, clean):
        from repro.configs import get_config
        from repro.launch.serve import build_engine

        cfg = get_config("llama3.2-1b", smoke=True)
        if cfg.use_pallas:
            cfg = cfg.replace(use_pallas=False)
        engine = build_engine(cfg, batch=1, max_seq=8)
        assert engine._step_plan is None
