"""Tests: HLO collective parsing, roofline terms, scan correction."""

import numpy as np
import pytest

from repro.analysis.hlo import collective_bytes
from repro.analysis.roofline import (model_flops, roofline_terms,
                                     scan_corrected)
from repro.configs import SHAPES, get_config


class TestCollectiveParsing:
    def test_parses_ops_and_sizes(self):
        hlo = """
  %ar = f32[16,1024]{1,0} all-reduce(f32[16,1024]{1,0} %x), replica_groups={}
  %ag = bf16[64,512]{1,0} all-gather(bf16[8,512]{1,0} %y), dimensions={0}
  %rs = f32[8,128]{1,0} reduce-scatter(f32[64,128]{1,0} %z), dimensions={0}
  %cp = f32[4,4]{1,0} collective-permute(f32[4,4]{1,0} %w)
  %aa = f32[16,16]{1,0} all-to-all(f32[16,16]{1,0} %v)
"""
        st = collective_bytes(hlo)
        assert st.per_op_count == {"all-reduce": 1, "all-gather": 1,
                                   "reduce-scatter": 1,
                                   "collective-permute": 1, "all-to-all": 1}
        assert st.per_op_bytes["all-reduce"] == 2 * 16 * 1024 * 4
        assert st.per_op_bytes["all-gather"] == 64 * 512 * 2
        assert st.per_op_bytes["reduce-scatter"] == 64 * 128 * 4
        assert st.per_op_bytes["collective-permute"] == 4 * 4 * 4
        assert st.per_op_bytes["all-to-all"] == 16 * 16 * 4

    def test_ignores_non_collectives(self):
        hlo = "%d = f32[128,128]{1,0} dot(f32[128,64] %a, f32[64,128] %b)"
        assert collective_bytes(hlo).total_wire_bytes == 0

    def test_real_compiled_module(self):
        import jax, jax.numpy as jnp
        # single-device psum-free module has no collectives
        c = jax.jit(lambda x: x @ x).lower(
            jax.ShapeDtypeStruct((64, 64), jnp.float32)).compile()
        assert collective_bytes(c.as_text()).total_wire_bytes == 0


class TestScanCorrection:
    def test_linear_extrapolation(self):
        # base=10, per_group=5: c1=15, c2=20 -> G=8: 10+40=50
        assert scan_corrected(15.0, 20.0, 8) == pytest.approx(50.0)

    def test_identity_for_one_group(self):
        assert scan_corrected(15.0, 20.0, 1) == pytest.approx(15.0)


class TestRoofline:
    def test_terms_and_dominance(self):
        t = roofline_terms(
            "a", "s", "single", 256,
            hlo_flops=1e15, hlo_bytes=1e12,
            collective_wire_per_device=1e9, mf=8e14)
        # compute = 1e15/(256*197e12) ~ 19.8us... memory = 1e12/(256*819e9)
        assert t.compute_s == pytest.approx(1e15 / (256 * 197e12))
        assert t.memory_s == pytest.approx(1e12 / (256 * 819e9))
        assert t.collective_s == pytest.approx(1e9 / 50e9)
        assert t.dominant == "collective"
        assert t.useful_ratio == pytest.approx(0.8)

    def test_model_flops_dense_vs_moe(self):
        dense = get_config("llama3.2-1b")
        moe = get_config("qwen3-moe-235b-a22b")
        preset = SHAPES["train_4k"]
        mf_dense = model_flops(dense, preset)
        # 6 * N * tokens
        from repro.models import Model
        n = Model(dense).param_count()
        assert mf_dense == pytest.approx(
            6.0 * n * preset.global_batch * preset.seq_len)
        # MoE counts ACTIVE params only: well below total
        mf_moe = model_flops(moe, preset)
        n_total = Model(moe).param_count()
        assert mf_moe < 6.0 * n_total * preset.global_batch * preset.seq_len

    def test_decode_flops_use_one_token(self):
        cfg = get_config("llama3.2-1b")
        mf = model_flops(cfg, SHAPES["decode_32k"])
        from repro.models import Model
        n = Model(cfg).param_count()
        assert mf == pytest.approx(2.0 * n * 128)
